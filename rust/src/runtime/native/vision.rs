//! Native reference model for the vision (`cnn_*`) variants.
//!
//! Mirrors the split architecture the AOT artifacts implement, in pure
//! deterministic Rust (fixed f32 evaluation order — no reassociation):
//!
//! * **client** — a fixed Gabor-energy feature bank (quadrature cos/sin
//!   templates over the SynthCIFAR grating frequencies; phase-invariant,
//!   which is what makes the random-phase data learnable at all) followed
//!   by a trainable per-feature affine: `h_j = f_j·s_j + b_j` with
//!   `f_j = tanh(((c_j·x)² + (s_j·x)²)/Γ)`. θ_c = [s(q), b(q)].
//! * **aux head** — trainable linear map q → 10 (θ_a) for the client-local
//!   loss (HERON's ZO objective, Eq. 6).
//! * **server head** — same shape (θ_s), trained with FO on uploaded
//!   smashed batches (Eq. 7).
//!
//! The local/server losses are `LOSS_SCALE · CE_mean`; the scale is part of
//! the model definition (it sets the effective step size of both the ZO
//! estimator and the FO updates under the configured learning rates).
//!
//! ## Hot path
//!
//! The feature projection (batch × q × 768 MACs) is the only θ-independent
//! heavy stage, so it is memoized in a [`FeatureCache`] keyed by a content
//! hash of `x`: the h local steps plus the upload `client_fwd` a client
//! runs on one batch project it once. `zo_step_into` regenerates each
//! probe's `u` from its counter-based seed in fixed chunks (perturb pass /
//! update pass — the Remark-4 trick `zo::ZoSgd::alloc_free_step`
//! demonstrates), so no per-probe vector is ever materialized and peak
//! temporary memory is independent of `n_pert`. Every f32 reduction keeps
//! the exact evaluation order of the original batch path, so cached and
//! `_into` results are bit-identical to the allocating ones.

use crate::runtime::api::{ClientRuntime, ThetaLayout, ZoArgs, ZoStepRecord};
use crate::runtime::native::cache::{self, CacheStats, FeatureCache};
use crate::runtime::tensor::TensorRef;
use crate::zo::stream::two_point_zo_into;
use anyhow::Result;

pub const CLASSES: usize = 10;
pub const PIXELS: usize = 768; // 16 x 16 x 3
const GAMMA: f32 = 24.0;
const LOSS_SCALE: f32 = 8.0;
const HVP_EPS: f32 = 1e-3;
const GRID_H: usize = 16;
const GRID_W: usize = 16;
const CHANNELS: usize = 3;

pub struct VisionModel {
    pub q: usize,
    /// cos templates, q x PIXELS, row-major, L2-normalized
    tc: Vec<f32>,
    /// sin templates, q x PIXELS
    ts: Vec<f32>,
    /// memoized θ-independent feature projections, keyed by content hash
    cache: FeatureCache,
}

impl VisionModel {
    /// Build the deterministic feature bank: one (fu, fv, tint) grating per
    /// feature, enumerated over the same grid the SynthCIFAR classes use.
    pub fn new(q: usize) -> Self {
        let mut tc = vec![0.0f32; q * PIXELS];
        let mut ts = vec![0.0f32; q * PIXELS];
        let norm = ((PIXELS / 2) as f64).sqrt();
        let tau = std::f64::consts::TAU;
        let mut combos = Vec::with_capacity(36);
        for fu in 1..=3u32 {
            for fv in 1..=3u32 {
                for tint_i in 0..4u32 {
                    combos.push((fu, fv, tint_i));
                }
            }
        }
        for j in 0..q {
            let (fu, fv, tint_i) = combos[j % combos.len()];
            let tint = tint_i as f64 * (tau / 12.0);
            let mut p = 0usize;
            for h in 0..GRID_H {
                for w in 0..GRID_W {
                    let arg = tau
                        * (fu as f64 * h as f64 / GRID_H as f64
                            + fv as f64 * w as f64 / GRID_W as f64);
                    for c in 0..CHANNELS {
                        let phase = arg + c as f64 * tint;
                        tc[j * PIXELS + p] = (phase.cos() / norm) as f32;
                        ts[j * PIXELS + p] = (phase.sin() / norm) as f32;
                        p += 1;
                    }
                }
            }
        }
        VisionModel {
            q,
            tc,
            ts,
            cache: FeatureCache::new(),
        }
    }

    pub fn nc(&self) -> usize {
        2 * self.q
    }

    pub fn na(&self) -> usize {
        self.q * CLASSES + CLASSES
    }

    pub fn nl(&self) -> usize {
        self.nc() + self.na()
    }

    pub fn ns(&self) -> usize {
        self.q * CLASSES + CLASSES
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Phase-invariant energy features: batch x q.
    pub fn features(&self, x: &[f32]) -> Vec<f32> {
        let batch = x.len() / PIXELS;
        let mut f = vec![0.0f32; batch * self.q];
        for b in 0..batch {
            let xb = &x[b * PIXELS..(b + 1) * PIXELS];
            for j in 0..self.q {
                let tc = &self.tc[j * PIXELS..(j + 1) * PIXELS];
                let ts = &self.ts[j * PIXELS..(j + 1) * PIXELS];
                let mut zc = 0.0f32;
                let mut zs = 0.0f32;
                for p in 0..PIXELS {
                    zc += tc[p] * xb[p];
                    zs += ts[p] * xb[p];
                }
                f[b * self.q + j] = ((zc * zc + zs * zs) / GAMMA).tanh();
            }
        }
        f
    }

    /// Memoized [`Self::features`]: the projection is θ-independent, so one
    /// batch's matrix is shared by every entry invoked on it.
    fn features_cached(&self, x: &[f32]) -> std::sync::Arc<Vec<f32>> {
        let key = cache::hash_f32(0x5EED_F00D ^ self.q as u64, x);
        self.cache.get_or_compute(key, || self.features(x))
    }

    /// h = f * s + b over a feature batch, into a reused buffer.
    fn client_apply_into(&self, theta_c: &[f32], f: &[f32], out: &mut Vec<f32>) {
        let batch = f.len() / self.q;
        let (s, b) = theta_c.split_at(self.q);
        out.clear();
        out.resize(batch * self.q, 0.0);
        for i in 0..batch {
            for j in 0..self.q {
                out[i * self.q + j] = f[i * self.q + j] * s[j] + b[j];
            }
        }
    }

    pub fn client_fwd_into(&self, theta_c: &[f32], x: &[f32], out: &mut Vec<f32>) {
        let f = self.features_cached(x);
        self.client_apply_into(theta_c, &f, out);
    }

    pub fn client_fwd(&self, theta_c: &[f32], x: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.client_fwd_into(theta_c, x, &mut out);
        out
    }

    /// Linear head logits: batch x CLASSES from batch x q.
    fn head(&self, w: &[f32], h: &[f32]) -> Vec<f32> {
        let batch = h.len() / self.q;
        let (wm, wb) = w.split_at(self.q * CLASSES);
        let mut logits = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let hb = &h[b * self.q..(b + 1) * self.q];
            let lb = &mut logits[b * CLASSES..(b + 1) * CLASSES];
            lb.copy_from_slice(wb);
            for j in 0..self.q {
                let hj = hb[j];
                let row = &wm[j * CLASSES..(j + 1) * CLASSES];
                for c in 0..CLASSES {
                    lb[c] += hj * row[c];
                }
            }
        }
        logits
    }

    /// Mean CE (unscaled) and the batch-mean dlogits (p - onehot)/B.
    fn ce(&self, logits: &[f32], y: &[i32]) -> (f64, Vec<f32>) {
        let batch = y.len();
        let mut loss = 0.0f64;
        let mut d = vec![0.0f32; batch * CLASSES];
        for b in 0..batch {
            let lb = &logits[b * CLASSES..(b + 1) * CLASSES];
            let mut mx = f32::NEG_INFINITY;
            for &v in lb {
                mx = mx.max(v);
            }
            let mut se = 0.0f32;
            for &v in lb {
                se += (v - mx).exp();
            }
            let lse = mx + se.ln();
            let yi = (y[b].clamp(0, CLASSES as i32 - 1)) as usize;
            loss += (lse - lb[yi]) as f64;
            let db = &mut d[b * CLASSES..(b + 1) * CLASSES];
            for c in 0..CLASSES {
                db[c] = (lb[c] - lse).exp() / batch as f32;
            }
            db[yi] -= 1.0 / batch as f32;
        }
        (loss / batch as f64, d)
    }

    /// Scaled local loss over precomputed features, streamed one sample at
    /// a time through the caller's row scratch — no batch-sized
    /// temporaries. Per-row op order matches `client_apply`/`head`/`ce`
    /// exactly, and the f64 loss accumulation runs in the same batch
    /// order, so the result is bit-identical to the materialized path.
    fn loss_rows(
        &self,
        theta_l: &[f32],
        f: &[f32],
        y: &[i32],
        hrow: &mut [f32],
        lrow: &mut [f32],
    ) -> f32 {
        let q = self.q;
        let nc = self.nc();
        let (s, bias) = theta_l[..nc].split_at(q);
        let (wm, wb) = theta_l[nc..].split_at(q * CLASSES);
        let batch = y.len();
        let mut loss = 0.0f64;
        for b in 0..batch {
            let fb = &f[b * q..(b + 1) * q];
            for j in 0..q {
                hrow[j] = fb[j] * s[j] + bias[j];
            }
            lrow.copy_from_slice(wb);
            for j in 0..q {
                let hj = hrow[j];
                let row = &wm[j * CLASSES..(j + 1) * CLASSES];
                for c in 0..CLASSES {
                    lrow[c] += hj * row[c];
                }
            }
            let mut mx = f32::NEG_INFINITY;
            for &v in lrow.iter() {
                mx = mx.max(v);
            }
            let mut se = 0.0f32;
            for &v in lrow.iter() {
                se += (v - mx).exp();
            }
            let lse = mx + se.ln();
            let yi = (y[b].clamp(0, CLASSES as i32 - 1)) as usize;
            loss += (lse - lrow[yi]) as f64;
        }
        LOSS_SCALE * ((loss / batch as f64) as f32)
    }

    fn loss_from_features(&self, theta_l: &[f32], f: &[f32], y: &[i32]) -> f32 {
        let mut hrow = vec![0.0f32; self.q];
        let mut lrow = vec![0.0f32; CLASSES];
        self.loss_rows(theta_l, f, y, &mut hrow, &mut lrow)
    }

    pub fn local_loss(&self, theta_l: &[f32], x: &[f32], y: &[i32]) -> f32 {
        let f = self.features_cached(x);
        self.loss_from_features(theta_l, &f, y)
    }

    /// Analytic gradient of the scaled local loss wrt θ_l.
    pub fn local_grad(&self, theta_l: &[f32], f: &[f32], y: &[i32]) -> (f32, Vec<f32>) {
        let q = self.q;
        let nc = self.nc();
        let batch = y.len();
        let mut h = Vec::new();
        self.client_apply_into(&theta_l[..nc], f, &mut h);
        let logits = self.head(&theta_l[nc..], &h);
        let (loss, d) = self.ce(&logits, y);
        let wm = &theta_l[nc..nc + q * CLASSES];
        let mut g = vec![0.0f32; theta_l.len()];
        // head grads: gW[j,c] = sum_b h[b,j] d[b,c]; gb[c] = sum_b d[b,c]
        for b in 0..batch {
            let hb = &h[b * q..(b + 1) * q];
            let db = &d[b * CLASSES..(b + 1) * CLASSES];
            for j in 0..q {
                let gj = &mut g[nc + j * CLASSES..nc + (j + 1) * CLASSES];
                for c in 0..CLASSES {
                    gj[c] += hb[j] * db[c];
                }
            }
            let gb = &mut g[nc + q * CLASSES..];
            for c in 0..CLASSES {
                gb[c] += db[c];
            }
        }
        // client grads through gh = d W^T
        for b in 0..batch {
            let db = &d[b * CLASSES..(b + 1) * CLASSES];
            let fb = &f[b * q..(b + 1) * q];
            for j in 0..q {
                let row = &wm[j * CLASSES..(j + 1) * CLASSES];
                let mut gh = 0.0f32;
                for c in 0..CLASSES {
                    gh += db[c] * row[c];
                }
                g[j] += gh * fb[j]; // d/ds
                g[q + j] += gh; // d/db
            }
        }
        for v in &mut g {
            *v *= LOSS_SCALE;
        }
        (LOSS_SCALE * loss as f32, g)
    }

    /// One FO step on θ_l into a reused buffer; returns the loss at the
    /// pre-update point.
    pub fn fo_step_into(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        let f = self.features_cached(x);
        let (loss, g) = self.local_grad(theta_l, &f, y);
        out.clear();
        out.extend_from_slice(theta_l);
        for i in 0..out.len() {
            out[i] -= lr * g[i];
        }
        loss
    }

    /// One FO step on θ_l; returns (θ_l', loss at the pre-update point).
    pub fn fo_step(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        lr: f32,
    ) -> (Vec<f32>, f32) {
        let mut out = Vec::new();
        let loss = self.fo_step_into(theta_l, x, y, lr, &mut out);
        (out, loss)
    }

    /// One two-point ZO step (Eq. 6) with `n_pert` probes into a reused
    /// buffer — [`two_point_zo_into`] streams each probe's `u` in chunks
    /// (zero per-probe allocations, temp memory independent of `n_pert`)
    /// while this method supplies the cached-feature streamed loss. Same
    /// value stream and same accumulation order as the materialized
    /// formulation, hence bit-identical results. `record_gscale` observes
    /// each probe's gradient scalar (the lean wire record); recording
    /// changes nothing numerically.
    #[allow(clippy::too_many_arguments)]
    pub fn zo_step_probes_into(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
        out: &mut Vec<f32>,
        record_gscale: impl FnMut(f32),
    ) -> f32 {
        let f = self.features_cached(x);
        let mut hrow = vec![0.0f32; self.q];
        let mut lrow = vec![0.0f32; CLASSES];
        let base = self.loss_rows(theta_l, &f, y, &mut hrow, &mut lrow);
        two_point_zo_into(
            theta_l,
            seed,
            mu,
            lr,
            n_pert,
            base,
            |pert| self.loss_rows(pert, &f, y, &mut hrow, &mut lrow),
            out,
            record_gscale,
        );
        base
    }

    /// [`Self::zo_step_probes_into`] without the probe record.
    #[allow(clippy::too_many_arguments)]
    pub fn zo_step_into(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
        out: &mut Vec<f32>,
    ) -> f32 {
        self.zo_step_probes_into(
            theta_l, x, y, seed, mu, lr, n_pert, out, |_| {},
        )
    }

    /// One two-point ZO step (Eq. 6); see [`Self::zo_step_into`].
    pub fn zo_step(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
    ) -> (Vec<f32>, f32) {
        let mut out = Vec::new();
        let loss =
            self.zo_step_into(theta_l, x, y, seed, mu, lr, n_pert, &mut out);
        (out, loss)
    }

    /// Server FO update on an uploaded smashed batch (Eq. 7) into reused
    /// buffers. Returns the loss; fills `cut` with dL/d smashed if given.
    pub fn server_step_into(
        &self,
        theta_s: &[f32],
        smashed: &[f32],
        y: &[i32],
        lr: f32,
        cut: Option<&mut Vec<f32>>,
        out: &mut Vec<f32>,
    ) -> f32 {
        let q = self.q;
        let batch = y.len();
        let logits = self.head(theta_s, smashed);
        let (loss, d) = self.ce(&logits, y);
        out.clear();
        out.extend_from_slice(theta_s);
        for b in 0..batch {
            let hb = &smashed[b * q..(b + 1) * q];
            let db = &d[b * CLASSES..(b + 1) * CLASSES];
            for j in 0..q {
                let row = &mut out[j * CLASSES..(j + 1) * CLASSES];
                for c in 0..CLASSES {
                    row[c] -= lr * LOSS_SCALE * hb[j] * db[c];
                }
            }
            let off = q * CLASSES;
            for c in 0..CLASSES {
                out[off + c] -= lr * LOSS_SCALE * db[c];
            }
        }
        if let Some(g) = cut {
            let wm = &theta_s[..q * CLASSES];
            g.clear();
            g.resize(batch * q, 0.0);
            for b in 0..batch {
                let db = &d[b * CLASSES..(b + 1) * CLASSES];
                for j in 0..q {
                    let row = &wm[j * CLASSES..(j + 1) * CLASSES];
                    let mut s = 0.0f32;
                    for c in 0..CLASSES {
                        s += db[c] * row[c];
                    }
                    g[b * q + j] = LOSS_SCALE * s;
                }
            }
        }
        LOSS_SCALE * loss as f32
    }

    /// Server FO update on an uploaded smashed batch (Eq. 7). Returns
    /// (θ_s', loss, optional cut gradient dL/d smashed).
    pub fn server_step(
        &self,
        theta_s: &[f32],
        smashed: &[f32],
        y: &[i32],
        lr: f32,
        want_cutgrad: bool,
    ) -> (Vec<f32>, f32, Option<Vec<f32>>) {
        let mut out = Vec::new();
        let mut cut = Vec::new();
        let loss = self.server_step_into(
            theta_s,
            smashed,
            y,
            lr,
            if want_cutgrad { Some(&mut cut) } else { None },
            &mut out,
        );
        (out, loss, if want_cutgrad { Some(cut) } else { None })
    }

    /// Client backprop step from a relayed cut gradient (SFLV1/V2).
    pub fn client_bp_step_into(
        &self,
        theta_c: &[f32],
        x: &[f32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) {
        let q = self.q;
        let f = self.features_cached(x);
        let batch = f.len() / q;
        out.clear();
        out.extend_from_slice(theta_c);
        for b in 0..batch {
            let gb = &g_smashed[b * q..(b + 1) * q];
            let fb = &f[b * q..(b + 1) * q];
            for j in 0..q {
                out[j] -= lr * gb[j] * fb[j];
                out[q + j] -= lr * gb[j];
            }
        }
    }

    /// Client backprop step from a relayed cut gradient (SFLV1/V2).
    pub fn client_bp_step(
        &self,
        theta_c: &[f32],
        x: &[f32],
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.client_bp_step_into(theta_c, x, g_smashed, lr, &mut out);
        out
    }

    /// FSL-SAGE aux alignment: one Gauss-Newton-style step moving the aux
    /// head's cut gradient toward the server's (δ̂ frozen).
    pub fn aux_align_into(
        &self,
        theta_l: &[f32],
        smashed: &[f32],
        y: &[i32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) {
        let q = self.q;
        let nc = self.nc();
        let batch = y.len();
        let logits = self.head(&theta_l[nc..], smashed);
        let (_, d) = self.ce(&logits, y);
        let wm = &theta_l[nc..nc + q * CLASSES];
        // g_aux[b,j] = LOSS_SCALE * sum_c d[b,c] W[j,c]
        out.clear();
        out.extend_from_slice(theta_l);
        for b in 0..batch {
            let db = &d[b * CLASSES..(b + 1) * CLASSES];
            let gs = &g_smashed[b * q..(b + 1) * q];
            for j in 0..q {
                let row = &wm[j * CLASSES..(j + 1) * CLASSES];
                let mut ga = 0.0f32;
                for c in 0..CLASSES {
                    ga += db[c] * row[c];
                }
                let diff = LOSS_SCALE * ga - gs[j];
                let o = &mut out[nc + j * CLASSES..nc + (j + 1) * CLASSES];
                for c in 0..CLASSES {
                    o[c] -= lr * diff * LOSS_SCALE * db[c];
                }
            }
        }
    }

    pub fn aux_align(
        &self,
        theta_l: &[f32],
        smashed: &[f32],
        y: &[i32],
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.aux_align_into(theta_l, smashed, y, g_smashed, lr, &mut out);
        out
    }

    /// Assembled-model evaluation: (correct count, total) on a batch.
    pub fn eval(
        &self,
        theta_c: &[f32],
        theta_s: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> (f32, f32) {
        let h = self.client_fwd(theta_c, x);
        let logits = self.head(theta_s, &h);
        let batch = y.len();
        let mut correct = 0u32;
        for b in 0..batch {
            let lb = &logits[b * CLASSES..(b + 1) * CLASSES];
            let mut arg = 0usize;
            for c in 1..CLASSES {
                if lb[c] > lb[arg] {
                    arg = c;
                }
            }
            if arg as i32 == y[b] {
                correct += 1;
            }
        }
        (correct as f32, batch as f32)
    }

    /// Hessian-vector product of the scaled local loss via central finite
    /// differences of the analytic gradient (symmetric to O(ε²)).
    pub fn hvp(
        &self,
        theta_l: &[f32],
        x: &[f32],
        y: &[i32],
        v: &[f32],
    ) -> Vec<f32> {
        let f = self.features_cached(x);
        let d = theta_l.len();
        let mut plus = theta_l.to_vec();
        let mut minus = theta_l.to_vec();
        for i in 0..d {
            plus[i] += HVP_EPS * v[i];
            minus[i] -= HVP_EPS * v[i];
        }
        let (_, gp) = self.local_grad(&plus, &f, y);
        let (_, gm) = self.local_grad(&minus, &f, y);
        let mut hv = vec![0.0f32; d];
        for i in 0..d {
            hv[i] = (gp[i] - gm[i]) / (2.0 * HVP_EPS);
        }
        hv
    }
}

// ---------------------------------------------------------------------------
// typed runtime surface
// ---------------------------------------------------------------------------

impl ClientRuntime for VisionModel {
    fn layout(&self) -> ThetaLayout {
        ThetaLayout {
            nc: self.nc(),
            na: self.na(),
            ns: self.ns(),
            nb: 0,
        }
    }

    fn zo_step(
        &self,
        _base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        zo: ZoArgs,
        out: &mut Vec<f32>,
        rec: &mut ZoStepRecord,
    ) -> Result<()> {
        let x = x.as_f32()?;
        rec.seed = zo.seed;
        rec.gscales.clear();
        let gs = &mut rec.gscales;
        rec.loss = self.zo_step_probes_into(
            theta_l,
            x,
            y,
            zo.seed,
            zo.mu,
            zo.lr,
            zo.n_pert,
            out,
            |g| gs.push(g),
        );
        Ok(())
    }

    fn fo_step(
        &self,
        _base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<f32> {
        Ok(self.fo_step_into(theta_l, x.as_f32()?, y, lr, out))
    }

    fn client_fwd(
        &self,
        _base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.client_fwd_into(theta_c, x.as_f32()?, out);
        Ok(())
    }

    fn server_step(
        &self,
        _base: Option<&[f32]>,
        theta_s: &[f32],
        smashed: &[f32],
        y: &[i32],
        lr: f32,
        cut: Option<&mut Vec<f32>>,
        out: &mut Vec<f32>,
    ) -> Result<f32> {
        Ok(self.server_step_into(theta_s, smashed, y, lr, cut, out))
    }

    fn client_bp_step(
        &self,
        _base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.client_bp_step_into(theta_c, x.as_f32()?, g_smashed, lr, out);
        Ok(())
    }

    fn aux_align(
        &self,
        _base: Option<&[f32]>,
        theta_l: &[f32],
        smashed: &[f32],
        y: &[i32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.aux_align_into(theta_l, smashed, y, g_smashed, lr, out);
        Ok(())
    }

    fn eval_full(
        &self,
        _base: Option<&[f32]>,
        theta_c: &[f32],
        theta_s: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
    ) -> Result<(f32, f32)> {
        Ok(self.eval(theta_c, theta_s, x.as_f32()?, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_vision;
    use crate::zo::stream::{fold_seed, PerturbStream};

    fn model() -> VisionModel {
        VisionModel::new(36)
    }

    fn batch(n: usize) -> (Vec<f32>, Vec<i32>) {
        synth_vision::batch(99, 0, n)
    }

    fn init_theta(m: &VisionModel) -> Vec<f32> {
        let mut t = vec![0.0f32; m.nl()];
        for j in 0..m.q {
            t[j] = 2.0;
        }
        t
    }

    #[test]
    fn features_are_phase_invariant_and_informative() {
        let m = model();
        let (x, y) = batch(64);
        let f = m.features(&x);
        // same-class feature vectors should be far more similar than the
        // raw pixels (which are decorrelated by the random phase)
        let mut same = 0.0f64;
        let mut diff = 0.0f64;
        let (mut ns, mut nd) = (0, 0);
        for a in 0..16 {
            for b in (a + 1)..16 {
                let dist: f64 = (0..m.q)
                    .map(|j| {
                        let d = f[a * m.q + j] - f[b * m.q + j];
                        (d * d) as f64
                    })
                    .sum();
                if y[a] == y[b] {
                    same += dist;
                    ns += 1;
                } else {
                    diff += dist;
                    nd += 1;
                }
            }
        }
        if ns > 0 && nd > 0 {
            assert!(same / ns as f64 <= diff / nd as f64 * 0.8);
        }
    }

    #[test]
    fn cached_features_bit_identical_and_counted() {
        let m = model();
        let (x, _) = batch(16);
        let direct = m.features(&x);
        let c1 = m.features_cached(&x);
        let c2 = m.features_cached(&x);
        assert_eq!(&*c1, &direct);
        assert_eq!(&*c2, &direct);
        let st = m.cache_stats();
        assert_eq!(st.misses, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(st.bytes_avoided as usize, direct.len() * 4);
    }

    #[test]
    fn streamed_loss_matches_materialized_path() {
        let m = model();
        let (x, y) = batch(32);
        let th = init_theta(&m);
        let f = m.features(&x);
        // materialized reference: full h + logits + batch ce
        let mut h = Vec::new();
        m.client_apply_into(&th[..m.nc()], &f, &mut h);
        let logits = m.head(&th[m.nc()..], &h);
        let (l, _) = m.ce(&logits, &y);
        let reference = LOSS_SCALE * ((l) as f32);
        let streamed = m.loss_from_features(&th, &f, &y);
        assert_eq!(streamed.to_bits(), reference.to_bits());
    }

    #[test]
    fn fo_step_descends() {
        let m = model();
        let (x, y) = batch(32);
        let mut th = init_theta(&m);
        let l0 = m.local_loss(&th, &x, &y);
        for _ in 0..5 {
            let (t2, _) = m.fo_step(&th, &x, &y, 2e-3);
            th = t2;
        }
        let l1 = m.local_loss(&th, &x, &y);
        assert!(l1 < l0, "fo did not descend: {l0} -> {l1}");
    }

    #[test]
    fn zo_step_deterministic_and_seed_sensitive() {
        let m = model();
        let (x, y) = batch(32);
        let th = init_theta(&m);
        let (a, la) = m.zo_step(&th, &x, &y, 7, 1e-2, 1e-3, 1);
        let (b, lb) = m.zo_step(&th, &x, &y, 7, 1e-2, 1e-3, 1);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = m.zo_step(&th, &x, &y, 8, 1e-2, 1e-3, 1);
        assert_ne!(a, c);
    }

    #[test]
    fn chunked_zo_matches_materialized_reference() {
        // reference: the pre-refactor formulation with a materialized u
        // per probe (take_vec) and a separate delta vector
        let m = model();
        let (x, y) = batch(16);
        let th = init_theta(&m);
        let d = th.len();
        let (seed, mu, lr, n_pert) = (0x5EED, 1e-2f32, 2e-3f32, 3usize);
        let f = m.features(&x);
        let base = m.loss_from_features(&th, &f, &y);
        let mut delta = vec![0.0f32; d];
        let mut pert = vec![0.0f32; d];
        for k in 0..n_pert {
            let u = PerturbStream::new(fold_seed(seed as u32, k as u32))
                .take_vec(d);
            for i in 0..d {
                pert[i] = th[i] + mu * u[i];
            }
            let lp = m.loss_from_features(&pert, &f, &y);
            let gscale = (lp - base) / mu * (lr / n_pert as f32);
            for i in 0..d {
                delta[i] -= gscale * u[i];
            }
        }
        let mut want = th.clone();
        for i in 0..d {
            want[i] += delta[i];
        }
        let (got, lbase) =
            m.zo_step(&th, &x, &y, seed, mu, lr, n_pert as i32);
        assert_eq!(lbase.to_bits(), base.to_bits());
        for i in 0..d {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "elem {i}");
        }
    }

    #[test]
    fn zo_probe_record_replays_bitwise() {
        let m = model();
        let (x, y) = batch(16);
        let th = init_theta(&m);
        let (seed, mu, lr, np) = (0x5EED, 1e-2f32, 2e-3f32, 3i32);
        let mut out = Vec::new();
        let mut gs = Vec::new();
        let base = m.zo_step_probes_into(
            &th, &x, &y, seed, mu, lr, np, &mut out, |g| gs.push(g),
        );
        // recording is invisible to the step itself
        let (want, lbase) = m.zo_step(&th, &x, &y, seed, mu, lr, np);
        assert_eq!(base.to_bits(), lbase.to_bits());
        assert_eq!(out, want);
        assert_eq!(gs.len(), np as usize);
        // (seed, gscales) alone reproduce θ' bit for bit
        let mut replayed = Vec::new();
        crate::zo::stream::replay_update(&th, seed, &gs, &mut replayed);
        assert_eq!(replayed, want);
        // and the typed trait surface agrees with the direct call
        let mut rec = ZoStepRecord::default();
        let mut tout = Vec::new();
        ClientRuntime::zo_step(
            &m,
            None,
            &th,
            TensorRef::F32(&x),
            &y,
            ZoArgs { seed, mu, lr, n_pert: np },
            &mut tout,
            &mut rec,
        )
        .unwrap();
        assert_eq!(tout, want);
        assert_eq!(rec.loss.to_bits(), base.to_bits());
        assert_eq!(rec.gscales, gs);
        assert_eq!(rec.seed, seed);
    }

    #[test]
    fn analytic_grad_matches_directional_fd() {
        let m = model();
        let (x, y) = batch(16);
        let th = init_theta(&m);
        let f = m.features(&x);
        let (_, g) = m.local_grad(&th, &f, &y);
        // directional derivative along a dense direction
        let dir: Vec<f32> = (0..th.len())
            .map(|i| ((i as f32 * 0.7).sin()) * 0.5)
            .collect();
        let eps = 1e-3f32;
        let mut tp = th.clone();
        let mut tm = th.clone();
        for i in 0..th.len() {
            tp[i] += eps * dir[i];
            tm[i] -= eps * dir[i];
        }
        let fd = (m.local_loss(&tp, &x, &y) - m.local_loss(&tm, &x, &y))
            / (2.0 * eps);
        let an: f64 = g
            .iter()
            .zip(&dir)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!(
            (fd as f64 - an).abs() < 0.05 * an.abs().max(0.1),
            "fd {fd} vs analytic {an}"
        );
    }

    #[test]
    fn server_step_reduces_its_batch_loss() {
        let m = model();
        let (x, y) = batch(32);
        let th_c = init_theta(&m)[..m.nc()].to_vec();
        let h = m.client_fwd(&th_c, &x);
        let mut ts = vec![0.0f32; m.ns()];
        let (_, l0, _) = m.server_step(&ts, &h, &y, 0.0, false);
        for _ in 0..5 {
            let (t2, _, _) = m.server_step(&ts, &h, &y, 2e-3, false);
            ts = t2;
        }
        let (_, l1, _) = m.server_step(&ts, &h, &y, 0.0, false);
        assert!(l1 < l0, "server did not descend: {l0} -> {l1}");
    }

    #[test]
    fn cutgrad_shape_and_effect() {
        let m = model();
        let (x, y) = batch(8);
        let th_c = init_theta(&m)[..m.nc()].to_vec();
        let h = m.client_fwd(&th_c, &x);
        // a few warm-up server steps so W != 0 and the cut gradient is live
        let mut ts = vec![0.0f32; m.ns()];
        for _ in 0..3 {
            ts = m.server_step(&ts, &h, &y, 1e-2, false).0;
        }
        let (_, _, g) = m.server_step(&ts, &h, &y, 1e-2, true);
        let g = g.unwrap();
        assert_eq!(g.len(), 8 * m.q);
        assert!(g.iter().any(|&v| v != 0.0));
        let t2 = m.client_bp_step(&th_c, &x, &g, 1e-3);
        assert_ne!(t2, th_c);
    }

    #[test]
    fn eval_counts_bounded() {
        let m = model();
        let (x, y) = batch(64);
        let th = init_theta(&m);
        let ts = vec![0.0f32; m.ns()];
        let (s1, s2) = m.eval(&th[..m.nc()], &ts, &x, &y);
        assert!(s1 >= 0.0 && s1 <= s2);
        assert_eq!(s2, 64.0);
    }

    #[test]
    fn hvp_is_symmetric_bilinear_probe() {
        let m = VisionModel::new(18);
        let (x, y) = batch(8);
        let th = {
            let mut t = vec![0.0f32; m.nl()];
            for j in 0..m.q {
                t[j] = 2.0;
            }
            t
        };
        let va: Vec<f32> = (0..m.nl()).map(|i| ((i * 13 % 7) as f32 - 3.0) * 0.1).collect();
        let vb: Vec<f32> = (0..m.nl()).map(|i| ((i * 5 % 11) as f32 - 5.0) * 0.1).collect();
        let hva = m.hvp(&th, &x, &y, &va);
        let hvb = m.hvp(&th, &x, &y, &vb);
        let ab: f64 = vb.iter().zip(&hva).map(|(&a, &b)| a as f64 * b as f64).sum();
        let ba: f64 = va.iter().zip(&hvb).map(|(&a, &b)| a as f64 * b as f64).sum();
        assert!(
            (ab - ba).abs() < 0.1 * ab.abs().max(0.2),
            "v^T H u = {ba} vs u^T H v = {ab}"
        );
    }
}
