//! Native reference model for the LM (`gpt2*`) variants.
//!
//! A LoRA-flavoured split bigram model over the SynthE2E byte stream:
//!
//! * **frozen base** — a fixed token embedding table E0 (vocab × e), the
//!   "pretrained" weights shipped as the `frozen_base` blob.
//! * **client** — a trainable additive delta table ΔE (θ_c, init 0; the
//!   LoRA adapter): `h[t] = tanh(E0[x_t] + ΔE[x_t])`.
//! * **aux head** — maps h → vocab logits for the client-local next-token
//!   loss. Capacity varies by variant (`a0` bias-only, `a1` linear,
//!   `a2`/`a3` one hidden tanh layer), the Fig 6 ablation axis.
//! * **server head** — linear e → vocab (θ_s), FO-trained on uploads.
//!
//! Losses are next-token CE means over non-PAD targets. FO updates
//! (server, fo_step, bp, alignment) use **sum reduction** over the valid
//! token positions — the reference optimizer semantics that make the
//! configured per-step learning rates effective at this scale. The ZO
//! entry perturbs against the mean loss directly (Eq. 6).

use crate::zo::stream::{fold_seed, PerturbStream};

pub const VOCAB: usize = 96;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuxKind {
    /// `a0`: bias-only unembed (the paper's minimal LN+unembed analog)
    Bias,
    /// `a1`: linear e -> vocab
    Linear,
    /// `a2`/`a3`: one hidden tanh layer of the given width
    Mlp(usize),
}

impl AuxKind {
    pub fn size(&self, e: usize) -> usize {
        match self {
            AuxKind::Bias => VOCAB,
            AuxKind::Linear => e * VOCAB + VOCAB,
            AuxKind::Mlp(k) => e * k + k + k * VOCAB + VOCAB,
        }
    }
}

pub struct LmModel {
    pub e: usize,
    pub aux: AuxKind,
}

/// Per-position dlogits with PAD masking; `scale` folds in the reduction.
struct CeOut {
    /// mean NLL over valid positions (unscaled)
    mean: f64,
    /// total NLL over valid positions
    sum: f64,
    /// number of valid (non-PAD-target) positions
    count: usize,
    /// (p - onehot) per valid position, zero at masked ones; batch*(seq-1)*V
    dlogits: Vec<f32>,
}

impl LmModel {
    pub fn new(e: usize, aux: AuxKind) -> Self {
        LmModel { e, aux }
    }

    pub fn nc(&self) -> usize {
        VOCAB * self.e
    }

    pub fn na(&self) -> usize {
        self.aux.size(self.e)
    }

    pub fn nl(&self) -> usize {
        self.nc() + self.na()
    }

    pub fn ns(&self) -> usize {
        self.e * VOCAB + VOCAB
    }

    /// h[b,t,:] = tanh(E0[tok] + ΔE[tok]); x is batch*seq tokens.
    pub fn client_fwd(&self, base: &[f32], theta_c: &[f32], x: &[i32]) -> Vec<f32> {
        let e = self.e;
        let n = x.len();
        let mut h = vec![0.0f32; n * e];
        for (i, &tok) in x.iter().enumerate() {
            let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
            let b0 = &base[t * e..(t + 1) * e];
            let d0 = &theta_c[t * e..(t + 1) * e];
            let out = &mut h[i * e..(i + 1) * e];
            for j in 0..e {
                out[j] = (b0[j] + d0[j]).tanh();
            }
        }
        h
    }

    /// Linear-head CE over shifted targets. `w` is [W(e*V), b(V)].
    fn linear_head_ce(&self, w: &[f32], h: &[f32], x: &[i32], seq: usize) -> CeOut {
        let e = self.e;
        let batch = x.len() / seq;
        let (wm, wb) = w.split_at(e * VOCAB);
        let tpos = seq - 1;
        let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
        let mut sum = 0.0f64;
        let mut count = 0usize;
        let mut logits = vec![0.0f32; VOCAB];
        for b in 0..batch {
            for t in 0..tpos {
                let tgt = x[b * seq + t + 1];
                if tgt <= 0 {
                    continue; // PAD target: masked out
                }
                let hv = &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                logits.copy_from_slice(wb);
                for j in 0..e {
                    let hj = hv[j];
                    let row = &wm[j * VOCAB..(j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        logits[v] += hj * row[v];
                    }
                }
                let (nll, probs) = log_softmax_nll(&logits, tgt as usize);
                sum += nll as f64;
                count += 1;
                let db = &mut dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                db.copy_from_slice(&probs);
                db[tgt as usize] -= 1.0;
            }
        }
        CeOut {
            mean: sum / count.max(1) as f64,
            sum,
            count,
            dlogits,
        }
    }

    /// Local (aux-head) mean loss for ZO / reporting.
    pub fn local_loss(&self, base: &[f32], theta_l: &[f32], x: &[i32], seq: usize) -> f32 {
        let h = self.client_fwd(base, &theta_l[..self.nc()], x);
        self.aux_ce(&theta_l[self.nc()..], &h, x, seq).mean as f32
    }

    fn aux_ce(&self, wa: &[f32], h: &[f32], x: &[i32], seq: usize) -> CeOut {
        let e = self.e;
        match self.aux {
            AuxKind::Linear => self.linear_head_ce(wa, h, x, seq),
            AuxKind::Bias => {
                // logits independent of h: just the bias
                let batch = x.len() / seq;
                let tpos = seq - 1;
                let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
                let mut sum = 0.0f64;
                let mut count = 0usize;
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let (nll, probs) =
                            log_softmax_nll(wa, tgt as usize);
                        sum += nll as f64;
                        count += 1;
                        let db = &mut dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        db.copy_from_slice(&probs);
                        db[tgt as usize] -= 1.0;
                    }
                }
                CeOut {
                    mean: sum / count.max(1) as f64,
                    sum,
                    count,
                    dlogits,
                }
            }
            AuxKind::Mlp(k) => {
                // z1 = tanh(h W1 + b1); logits = z1 W2 + b2
                let batch = x.len() / seq;
                let tpos = seq - 1;
                let (w1, rest) = wa.split_at(e * k);
                let (b1, rest) = rest.split_at(k);
                let (w2, b2) = rest.split_at(k * VOCAB);
                let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
                let mut sum = 0.0f64;
                let mut count = 0usize;
                let mut z1 = vec![0.0f32; k];
                let mut logits = vec![0.0f32; VOCAB];
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let hv = &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                        for m in 0..k {
                            let mut z = b1[m];
                            for j in 0..e {
                                z += hv[j] * w1[j * k + m];
                            }
                            z1[m] = z.tanh();
                        }
                        logits.copy_from_slice(b2);
                        for m in 0..k {
                            let zm = z1[m];
                            let row = &w2[m * VOCAB..(m + 1) * VOCAB];
                            for v in 0..VOCAB {
                                logits[v] += zm * row[v];
                            }
                        }
                        let (nll, probs) = log_softmax_nll(&logits, tgt as usize);
                        sum += nll as f64;
                        count += 1;
                        let db = &mut dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        db.copy_from_slice(&probs);
                        db[tgt as usize] -= 1.0;
                    }
                }
                CeOut {
                    mean: sum / count.max(1) as f64,
                    sum,
                    count,
                    dlogits,
                }
            }
        }
    }

    /// ZO step on θ_l against the aux-head mean loss.
    pub fn zo_step(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
    ) -> (Vec<f32>, f32) {
        let d = theta_l.len();
        let lbase = self.local_loss(base, theta_l, x, seq);
        let n_pert = n_pert.max(1) as usize;
        let mut delta = vec![0.0f32; d];
        let mut pert = vec![0.0f32; d];
        for k in 0..n_pert {
            let u = PerturbStream::new(fold_seed(seed as u32, k as u32))
                .take_vec(d);
            for i in 0..d {
                pert[i] = theta_l[i] + mu * u[i];
            }
            let lp = self.local_loss(base, &pert, x, seq);
            let gscale = (lp - lbase) / mu * (lr / n_pert as f32);
            for i in 0..d {
                delta[i] -= gscale * u[i];
            }
        }
        let mut th = theta_l.to_vec();
        for i in 0..d {
            th[i] += delta[i];
        }
        (th, lbase)
    }

    /// FO step on θ_l (aux head + ΔE), sum reduction.
    pub fn fo_step(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
    ) -> (Vec<f32>, f32) {
        let e = self.e;
        let nc = self.nc();
        let h = self.client_fwd(base, &theta_l[..nc], x);
        let out = self.aux_ce(&theta_l[nc..], &h, x, seq);
        let tpos = seq - 1;
        let batch = x.len() / seq;
        let mut th = theta_l.to_vec();
        // gradient of SUM nll: dlogits rows are (p - onehot) per position
        match self.aux {
            AuxKind::Bias => {
                let off = nc;
                for b in 0..batch {
                    for t in 0..tpos {
                        let db = &out.dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        for v in 0..VOCAB {
                            th[off + v] -= lr * db[v];
                        }
                    }
                }
            }
            AuxKind::Linear => {
                let wa: Vec<f32> = theta_l[nc..nc + e * VOCAB].to_vec();
                for b in 0..batch {
                    for t in 0..tpos {
                        let db = &out.dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        let pos = b * seq + t;
                        let hv = &h[pos * e..(pos + 1) * e];
                        // aux W/b grads
                        for j in 0..e {
                            let row = &mut th
                                [nc + j * VOCAB..nc + (j + 1) * VOCAB];
                            for v in 0..VOCAB {
                                row[v] -= lr * hv[j] * db[v];
                            }
                        }
                        let boff = nc + e * VOCAB;
                        for v in 0..VOCAB {
                            th[boff + v] -= lr * db[v];
                        }
                        // ΔE grad through tanh'
                        let tok =
                            (x[pos].clamp(0, VOCAB as i32 - 1)) as usize;
                        for j in 0..e {
                            let row = &wa[j * VOCAB..(j + 1) * VOCAB];
                            let mut gh = 0.0f32;
                            for v in 0..VOCAB {
                                gh += db[v] * row[v];
                            }
                            let hj = hv[j];
                            th[tok * e + j] -= lr * gh * (1.0 - hj * hj);
                        }
                    }
                }
            }
            AuxKind::Mlp(_) => {
                // FO through the MLP aux is only exercised by the Fig 6
                // ablation; a plain SPSA-style fallback keeps it trainable
                // without a full hand-written backprop: reuse the ZO
                // estimator with a fixed probe count.
                let (t2, _) =
                    self.zo_step(base, theta_l, x, seq, 0x0F0E, 1e-2, lr, 4);
                th = t2;
            }
        }
        (th, out.mean as f32)
    }

    /// Server FO update (sum reduction); optionally the cut gradient.
    pub fn server_step(
        &self,
        theta_s: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
        want_cutgrad: bool,
    ) -> (Vec<f32>, f32, Option<Vec<f32>>) {
        let e = self.e;
        let out = self.linear_head_ce(theta_s, smashed, x, seq);
        let tpos = seq - 1;
        let batch = x.len() / seq;
        let mut th = theta_s.to_vec();
        for b in 0..batch {
            for t in 0..tpos {
                let db = &out.dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                let pos = b * seq + t;
                let hv = &smashed[pos * e..(pos + 1) * e];
                for j in 0..e {
                    let row = &mut th[j * VOCAB..(j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        row[v] -= lr * hv[j] * db[v];
                    }
                }
                let boff = e * VOCAB;
                for v in 0..VOCAB {
                    th[boff + v] -= lr * db[v];
                }
            }
        }
        let cut = if want_cutgrad {
            let wm = &theta_s[..e * VOCAB];
            let mut g = vec![0.0f32; smashed.len()];
            for b in 0..batch {
                for t in 0..tpos {
                    let db = &out.dlogits[(b * tpos + t) * VOCAB
                        ..(b * tpos + t + 1) * VOCAB];
                    let pos = b * seq + t;
                    let gv = &mut g[pos * e..(pos + 1) * e];
                    for j in 0..e {
                        let row = &wm[j * VOCAB..(j + 1) * VOCAB];
                        let mut s = 0.0f32;
                        for v in 0..VOCAB {
                            s += db[v] * row[v];
                        }
                        gv[j] = s;
                    }
                }
            }
            Some(g)
        } else {
            None
        };
        (th, out.mean as f32, cut)
    }

    /// Client backprop from the relayed cut gradient (SplitLoRA path).
    pub fn client_bp_step(
        &self,
        base: &[f32],
        theta_c: &[f32],
        x: &[i32],
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let e = self.e;
        let h = self.client_fwd(base, theta_c, x);
        let mut th = theta_c.to_vec();
        for (i, &tok) in x.iter().enumerate() {
            let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
            let hv = &h[i * e..(i + 1) * e];
            let gv = &g_smashed[i * e..(i + 1) * e];
            for j in 0..e {
                th[t * e + j] -= lr * gv[j] * (1.0 - hv[j] * hv[j]);
            }
        }
        th
    }

    /// FSL-SAGE alignment of the aux head toward the server cut gradient.
    pub fn aux_align(
        &self,
        base: &[f32],
        theta_l: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let _ = base;
        let e = self.e;
        let nc = self.nc();
        let mut th = theta_l.to_vec();
        if self.aux != AuxKind::Linear {
            // bias-only aux has no cut-gradient path to align; the MLP aux
            // alignment is not exercised by any configured baseline
            return th;
        }
        let out = self.aux_ce(&theta_l[nc..], smashed, x, seq);
        let wa = &theta_l[nc..nc + e * VOCAB];
        let tpos = seq - 1;
        let batch = x.len() / seq;
        for b in 0..batch {
            for t in 0..tpos {
                let db = &out.dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                let pos = b * seq + t;
                let gs = &g_smashed[pos * e..(pos + 1) * e];
                for j in 0..e {
                    let row = &wa[j * VOCAB..(j + 1) * VOCAB];
                    let mut ga = 0.0f32;
                    for v in 0..VOCAB {
                        ga += db[v] * row[v];
                    }
                    let diff = ga - gs[j];
                    let orow =
                        &mut th[nc + j * VOCAB..nc + (j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        orow[v] -= lr * diff * db[v];
                    }
                }
            }
        }
        th
    }

    /// (NLL sum, valid-token count) of the assembled client+server model.
    pub fn eval(
        &self,
        base: &[f32],
        theta_c: &[f32],
        theta_s: &[f32],
        x: &[i32],
        seq: usize,
    ) -> (f32, f32) {
        let h = self.client_fwd(base, theta_c, x);
        let out = self.linear_head_ce(theta_s, &h, x, seq);
        (out.sum as f32, out.count as f32)
    }
}

/// (nll, softmax probs) for one logits row and target index.
fn log_softmax_nll(logits: &[f32], target: usize) -> (f32, Vec<f32>) {
    let mut mx = f32::NEG_INFINITY;
    for &v in logits {
        mx = mx.max(v);
    }
    let mut se = 0.0f32;
    for &v in logits {
        se += (v - mx).exp();
    }
    let lse = mx + se.ln();
    let probs: Vec<f32> = logits.iter().map(|&v| (v - lse).exp()).collect();
    (lse - logits[target.min(logits.len() - 1)], probs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_text;
    use crate::zo::stream::{fold_seed, PerturbStream};

    const SEQ: usize = synth_text::SEQ_LEN;

    fn base(e: usize) -> Vec<f32> {
        PerturbStream::new(fold_seed(0xBA5E, 1))
            .take_vec(VOCAB * e)
            .into_iter()
            .map(|v| v * 0.3)
            .collect()
    }

    fn model() -> LmModel {
        LmModel::new(16, AuxKind::Linear)
    }

    #[test]
    fn uniform_head_gives_log_vocab_nll() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let th_c = vec![0.0f32; m.nc()];
        let ts = vec![0.0f32; m.ns()];
        let (nll, n) = m.eval(&b, &th_c, &ts, &x, SEQ);
        let per_tok = nll / n;
        assert!(
            (per_tok - (VOCAB as f32).ln()).abs() < 1e-3,
            "uniform ppl should be vocab-sized: per-token nll {per_tok}"
        );
    }

    #[test]
    fn server_steps_reduce_nll() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let th_c = vec![0.0f32; m.nc()];
        let h = m.client_fwd(&b, &th_c, &x);
        let mut ts = vec![0.0f32; m.ns()];
        let (_, l0, _) = m.server_step(&ts, &h, &x, SEQ, 0.0, false);
        for _ in 0..4 {
            ts = m.server_step(&ts, &h, &x, SEQ, 1e-3, false).0;
        }
        let (_, l1, _) = m.server_step(&ts, &h, &x, SEQ, 0.0, false);
        assert!(l1 < l0 * 0.97, "server NLL {l0} -> {l1}");
    }

    #[test]
    fn zo_step_deterministic() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 2);
        let th = vec![0.0f32; m.nl()];
        let (a, la) = m.zo_step(&b, &th, &x, SEQ, 42, 1e-2, 1e-3, 1);
        let (bb, lb) = m.zo_step(&b, &th, &x, SEQ, 42, 1e-2, 1e-3, 1);
        assert_eq!(a, bb);
        assert_eq!(la, lb);
        assert!((la - (VOCAB as f32).ln()).abs() < 0.05);
    }

    #[test]
    fn fo_step_descends_on_linear_aux() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let mut th = vec![0.0f32; m.nl()];
        let l0 = m.local_loss(&b, &th, &x, SEQ);
        for _ in 0..4 {
            th = m.fo_step(&b, &th, &x, SEQ, 1e-3).0;
        }
        let l1 = m.local_loss(&b, &th, &x, SEQ);
        assert!(l1 < l0 * 0.99, "aux NLL {l0} -> {l1}");
    }

    #[test]
    fn aux_sizes_per_kind() {
        assert_eq!(AuxKind::Bias.size(16), 96);
        assert_eq!(AuxKind::Linear.size(16), 16 * 96 + 96);
        assert_eq!(AuxKind::Mlp(8).size(16), 16 * 8 + 8 + 8 * 96 + 96);
    }

    #[test]
    fn pad_targets_are_masked() {
        let m = model();
        let b = base(16);
        // one real record (has trailing PADs) — count must be < seq-1
        let x = synth_text::batch(42, 0, 1);
        let th_c = vec![0.0f32; m.nc()];
        let ts = vec![0.0f32; m.ns()];
        let (_, n) = m.eval(&b, &th_c, &ts, &x, SEQ);
        assert!(n > 10.0 && n < (SEQ - 1) as f32);
    }
}
