//! Native reference model for the LM (`gpt2*`) variants.
//!
//! A LoRA-flavoured split bigram model over the SynthE2E byte stream:
//!
//! * **frozen base** — a fixed token embedding table E0 (vocab × e), the
//!   "pretrained" weights shipped as the `frozen_base` blob.
//! * **client** — a trainable additive delta table ΔE (θ_c, init 0; the
//!   LoRA adapter): `h[t] = tanh(E0[x_t] + ΔE[x_t])`.
//! * **aux head** — maps h → vocab logits for the client-local next-token
//!   loss. Capacity varies by variant (`a0` bias-only, `a1` linear,
//!   `a2`/`a3` one hidden tanh layer), the Fig 6 ablation axis.
//! * **server head** — linear e → vocab (θ_s), FO-trained on uploads.
//!
//! Losses are next-token CE means over non-PAD targets. FO updates
//! (server, fo_step, bp, alignment) use **sum reduction** over the valid
//! token positions — the reference optimizer semantics that make the
//! configured per-step learning rates effective at this scale. The ZO
//! entry perturbs against the mean loss directly (Eq. 6).
//!
//! ## Hot path
//!
//! The θ-independent part of the client forward — the E0 row gather for a
//! token batch — is memoized in a [`FeatureCache`] keyed by a content hash
//! of the batch, so the h local steps + upload on one batch gather it
//! once. `zo_step_into` streams each probe's perturbation in fixed chunks
//! (no per-probe `u` vector) and evaluates probe losses through the
//! allocation-free [`Self::aux_loss`] path; all op orders match the
//! materialized formulation bit for bit.

use crate::runtime::api::{ClientRuntime, ThetaLayout, ZoArgs, ZoStepRecord};
use crate::runtime::native::cache::{self, CacheStats, FeatureCache};
use crate::runtime::tensor::TensorRef;
use crate::zo::stream::two_point_zo_into;
use anyhow::{Context, Result};

pub const VOCAB: usize = 96;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AuxKind {
    /// `a0`: bias-only unembed (the paper's minimal LN+unembed analog)
    Bias,
    /// `a1`: linear e -> vocab
    Linear,
    /// `a2`/`a3`: one hidden tanh layer of the given width
    Mlp(usize),
}

impl AuxKind {
    pub fn size(&self, e: usize) -> usize {
        match self {
            AuxKind::Bias => VOCAB,
            AuxKind::Linear => e * VOCAB + VOCAB,
            AuxKind::Mlp(k) => e * k + k + k * VOCAB + VOCAB,
        }
    }
}

pub struct LmModel {
    pub e: usize,
    pub aux: AuxKind,
    /// tokens per record — fixes the batch geometry for the typed
    /// [`ClientRuntime`] surface (the entry path still threads it
    /// per call, with the same value)
    pub seq: usize,
    /// memoized θ-independent E0 row gathers, keyed by batch content hash
    cache: FeatureCache,
}

/// Per-position dlogits with PAD masking; `scale` folds in the reduction.
struct CeOut {
    /// mean NLL over valid positions (unscaled)
    mean: f64,
    /// total NLL over valid positions
    sum: f64,
    /// number of valid (non-PAD-target) positions
    count: usize,
    /// (p - onehot) per valid position, zero at masked ones; batch*(seq-1)*V
    dlogits: Vec<f32>,
}

impl LmModel {
    pub fn new(e: usize, aux: AuxKind, seq: usize) -> Self {
        LmModel {
            e,
            aux,
            seq,
            cache: FeatureCache::new(),
        }
    }

    pub fn nc(&self) -> usize {
        VOCAB * self.e
    }

    pub fn na(&self) -> usize {
        self.aux.size(self.e)
    }

    pub fn nl(&self) -> usize {
        self.nc() + self.na()
    }

    pub fn ns(&self) -> usize {
        self.e * VOCAB + VOCAB
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Hidden width of the MLP aux (0 otherwise) — sizes the z1 scratch.
    fn aux_hidden(&self) -> usize {
        match self.aux {
            AuxKind::Mlp(k) => k,
            _ => 0,
        }
    }

    /// Memoized E0 row gather for a token batch: `out[i, :] = E0[x_i, :]`
    /// (clamped tokens). θ-independent, so one gather serves every entry
    /// invoked on the batch — and `zo_step_into` fetches it **once** and
    /// reuses it across every probe. The key hashes the **full** base
    /// table (not a sampled fingerprint), so distinct base tables can
    /// never alias to the same cached gather. That read is a deliberate
    /// per-lookup cost: base arrives as a per-call argument with no
    /// identity the model may trust, and every cheaper fingerprint
    /// (length/ends sampling, pointer memos) reopens a silent-staleness
    /// hole; the probe loop amortizes it where it matters.
    fn base_rows_cached(
        &self,
        base: &[f32],
        x: &[i32],
    ) -> std::sync::Arc<Vec<f32>> {
        let key = cache::hash_i32(0xBA5E ^ self.e as u64, x)
            .rotate_left(17)
            ^ cache::hash_f32(0xE0_B45E, base);
        let e = self.e;
        self.cache.get_or_compute(key, || {
            let mut g = vec![0.0f32; x.len() * e];
            for (i, &tok) in x.iter().enumerate() {
                let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
                g[i * e..(i + 1) * e]
                    .copy_from_slice(&base[t * e..(t + 1) * e]);
            }
            g
        })
    }

    /// Client forward from pre-gathered E0 rows: the summands and their
    /// order equal the direct-gather formulation, so h is bit-identical.
    fn client_fwd_with_rows(
        &self,
        bg: &[f32],
        theta_c: &[f32],
        x: &[i32],
        out: &mut Vec<f32>,
    ) {
        let e = self.e;
        out.clear();
        out.resize(x.len() * e, 0.0);
        for (i, &tok) in x.iter().enumerate() {
            let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
            let b0 = &bg[i * e..(i + 1) * e];
            let d0 = &theta_c[t * e..(t + 1) * e];
            let o = &mut out[i * e..(i + 1) * e];
            for j in 0..e {
                o[j] = (b0[j] + d0[j]).tanh();
            }
        }
    }

    /// h[b,t,:] = tanh(E0[tok] + ΔE[tok]) into a reused buffer; x is
    /// batch*seq tokens. The E0 gather comes from the cache.
    pub fn client_fwd_into(
        &self,
        base: &[f32],
        theta_c: &[f32],
        x: &[i32],
        out: &mut Vec<f32>,
    ) {
        let bg = self.base_rows_cached(base, x);
        self.client_fwd_with_rows(&bg, theta_c, x, out);
    }

    /// h[b,t,:] = tanh(E0[tok] + ΔE[tok]); x is batch*seq tokens.
    pub fn client_fwd(&self, base: &[f32], theta_c: &[f32], x: &[i32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.client_fwd_into(base, theta_c, x, &mut out);
        out
    }

    /// Linear-head CE over shifted targets. `w` is [W(e*V), b(V)].
    fn linear_head_ce(&self, w: &[f32], h: &[f32], x: &[i32], seq: usize) -> CeOut {
        let e = self.e;
        let batch = x.len() / seq;
        let (wm, wb) = w.split_at(e * VOCAB);
        let tpos = seq - 1;
        let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
        let mut sum = 0.0f64;
        let mut count = 0usize;
        let mut logits = vec![0.0f32; VOCAB];
        for b in 0..batch {
            for t in 0..tpos {
                let tgt = x[b * seq + t + 1];
                if tgt <= 0 {
                    continue; // PAD target: masked out
                }
                let hv = &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                logits.copy_from_slice(wb);
                for j in 0..e {
                    let hj = hv[j];
                    let row = &wm[j * VOCAB..(j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        logits[v] += hj * row[v];
                    }
                }
                let (nll, probs) = log_softmax_nll(&logits, tgt as usize);
                sum += nll as f64;
                count += 1;
                let db = &mut dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                db.copy_from_slice(&probs);
                db[tgt as usize] -= 1.0;
            }
        }
        CeOut {
            mean: sum / count.max(1) as f64,
            sum,
            count,
            dlogits,
        }
    }

    /// Local (aux-head) mean loss for ZO / reporting.
    pub fn local_loss(&self, base: &[f32], theta_l: &[f32], x: &[i32], seq: usize) -> f32 {
        let mut h = Vec::new();
        self.client_fwd_into(base, &theta_l[..self.nc()], x, &mut h);
        let mut logits = vec![0.0f32; VOCAB];
        let mut z1 = vec![0.0f32; self.aux_hidden()];
        self.aux_loss(&theta_l[self.nc()..], &h, x, seq, &mut logits, &mut z1)
    }

    /// Allocation-free aux-head mean loss: identical traversal order,
    /// masking, and f64 accumulation as [`Self::aux_ce`], minus the
    /// dlogits/probs materialization — bit-identical mean, zero
    /// temporaries beyond the caller's row scratch.
    fn aux_loss(
        &self,
        wa: &[f32],
        h: &[f32],
        x: &[i32],
        seq: usize,
        logits: &mut [f32],
        z1: &mut [f32],
    ) -> f32 {
        let e = self.e;
        let batch = x.len() / seq;
        let tpos = seq - 1;
        let mut sum = 0.0f64;
        let mut count = 0usize;
        match self.aux {
            AuxKind::Bias => {
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        sum += nll_only(wa, tgt as usize) as f64;
                        count += 1;
                    }
                }
            }
            AuxKind::Linear => {
                let (wm, wb) = wa.split_at(e * VOCAB);
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let hv =
                            &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                        logits.copy_from_slice(wb);
                        for j in 0..e {
                            let hj = hv[j];
                            let row = &wm[j * VOCAB..(j + 1) * VOCAB];
                            for v in 0..VOCAB {
                                logits[v] += hj * row[v];
                            }
                        }
                        sum += nll_only(logits, tgt as usize) as f64;
                        count += 1;
                    }
                }
            }
            AuxKind::Mlp(k) => {
                let (w1, rest) = wa.split_at(e * k);
                let (b1, rest) = rest.split_at(k);
                let (w2, b2) = rest.split_at(k * VOCAB);
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let hv =
                            &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                        for m in 0..k {
                            let mut z = b1[m];
                            for j in 0..e {
                                z += hv[j] * w1[j * k + m];
                            }
                            z1[m] = z.tanh();
                        }
                        logits.copy_from_slice(b2);
                        for m in 0..k {
                            let zm = z1[m];
                            let row = &w2[m * VOCAB..(m + 1) * VOCAB];
                            for v in 0..VOCAB {
                                logits[v] += zm * row[v];
                            }
                        }
                        sum += nll_only(logits, tgt as usize) as f64;
                        count += 1;
                    }
                }
            }
        }
        (sum / count.max(1) as f64) as f32
    }

    fn aux_ce(&self, wa: &[f32], h: &[f32], x: &[i32], seq: usize) -> CeOut {
        let e = self.e;
        match self.aux {
            AuxKind::Linear => self.linear_head_ce(wa, h, x, seq),
            AuxKind::Bias => {
                // logits independent of h: just the bias
                let batch = x.len() / seq;
                let tpos = seq - 1;
                let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
                let mut sum = 0.0f64;
                let mut count = 0usize;
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let (nll, probs) =
                            log_softmax_nll(wa, tgt as usize);
                        sum += nll as f64;
                        count += 1;
                        let db = &mut dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        db.copy_from_slice(&probs);
                        db[tgt as usize] -= 1.0;
                    }
                }
                CeOut {
                    mean: sum / count.max(1) as f64,
                    sum,
                    count,
                    dlogits,
                }
            }
            AuxKind::Mlp(k) => {
                // z1 = tanh(h W1 + b1); logits = z1 W2 + b2
                let batch = x.len() / seq;
                let tpos = seq - 1;
                let (w1, rest) = wa.split_at(e * k);
                let (b1, rest) = rest.split_at(k);
                let (w2, b2) = rest.split_at(k * VOCAB);
                let mut dlogits = vec![0.0f32; batch * tpos * VOCAB];
                let mut sum = 0.0f64;
                let mut count = 0usize;
                let mut z1 = vec![0.0f32; k];
                let mut logits = vec![0.0f32; VOCAB];
                for b in 0..batch {
                    for t in 0..tpos {
                        let tgt = x[b * seq + t + 1];
                        if tgt <= 0 {
                            continue;
                        }
                        let hv = &h[(b * seq + t) * e..(b * seq + t + 1) * e];
                        for m in 0..k {
                            let mut z = b1[m];
                            for j in 0..e {
                                z += hv[j] * w1[j * k + m];
                            }
                            z1[m] = z.tanh();
                        }
                        logits.copy_from_slice(b2);
                        for m in 0..k {
                            let zm = z1[m];
                            let row = &w2[m * VOCAB..(m + 1) * VOCAB];
                            for v in 0..VOCAB {
                                logits[v] += zm * row[v];
                            }
                        }
                        let (nll, probs) = log_softmax_nll(&logits, tgt as usize);
                        sum += nll as f64;
                        count += 1;
                        let db = &mut dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        db.copy_from_slice(&probs);
                        db[tgt as usize] -= 1.0;
                    }
                }
                CeOut {
                    mean: sum / count.max(1) as f64,
                    sum,
                    count,
                    dlogits,
                }
            }
        }
    }

    /// ZO step on θ_l against the aux-head mean loss, into a reused
    /// buffer. Each probe's `u` is regenerated from its counter-based
    /// seed in fixed chunks (perturb pass / update pass), so temporary
    /// memory is O(d + chunk) regardless of `n_pert` and no per-probe
    /// vector is allocated; the value stream and accumulation order match
    /// the materialized formulation bit for bit. `record_gscale` observes
    /// each probe's gradient scalar (the lean wire record) without
    /// changing any arithmetic.
    #[allow(clippy::too_many_arguments)]
    pub fn zo_step_probes_into(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
        out: &mut Vec<f32>,
        record_gscale: impl FnMut(f32),
    ) -> f32 {
        let nc = self.nc();
        let mut h = Vec::new();
        let mut logits = vec![0.0f32; VOCAB];
        let mut z1 = vec![0.0f32; self.aux_hidden()];
        // one gather lookup for the whole step: every probe shares it
        let bg = self.base_rows_cached(base, x);
        self.client_fwd_with_rows(&bg, &theta_l[..nc], x, &mut h);
        let lbase =
            self.aux_loss(&theta_l[nc..], &h, x, seq, &mut logits, &mut z1);
        two_point_zo_into(
            theta_l,
            seed,
            mu,
            lr,
            n_pert,
            lbase,
            |pert| {
                self.client_fwd_with_rows(&bg, &pert[..nc], x, &mut h);
                self.aux_loss(&pert[nc..], &h, x, seq, &mut logits, &mut z1)
            },
            out,
            record_gscale,
        );
        lbase
    }

    /// [`Self::zo_step_probes_into`] without the probe record.
    #[allow(clippy::too_many_arguments)]
    pub fn zo_step_into(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
        out: &mut Vec<f32>,
    ) -> f32 {
        self.zo_step_probes_into(
            base, theta_l, x, seq, seed, mu, lr, n_pert, out, |_| {},
        )
    }

    /// ZO step on θ_l against the aux-head mean loss.
    pub fn zo_step(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        seed: i32,
        mu: f32,
        lr: f32,
        n_pert: i32,
    ) -> (Vec<f32>, f32) {
        let mut out = Vec::new();
        let loss = self.zo_step_into(
            base, theta_l, x, seq, seed, mu, lr, n_pert, &mut out,
        );
        (out, loss)
    }

    /// FO step on θ_l (aux head + ΔE), sum reduction, into a reused
    /// buffer; returns the pre-update mean loss.
    pub fn fo_step_into(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        let e = self.e;
        let nc = self.nc();
        let mut h = Vec::new();
        self.client_fwd_into(base, &theta_l[..nc], x, &mut h);
        let ce = self.aux_ce(&theta_l[nc..], &h, x, seq);
        let tpos = seq - 1;
        let batch = x.len() / seq;
        out.clear();
        out.extend_from_slice(theta_l);
        // gradient of SUM nll: dlogits rows are (p - onehot) per position
        match self.aux {
            AuxKind::Bias => {
                let off = nc;
                for b in 0..batch {
                    for t in 0..tpos {
                        let db = &ce.dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        for v in 0..VOCAB {
                            out[off + v] -= lr * db[v];
                        }
                    }
                }
            }
            AuxKind::Linear => {
                // reads come from the immutable θ_l, writes go to `out`,
                // so the pre-update weights need no defensive copy
                let wa = &theta_l[nc..nc + e * VOCAB];
                for b in 0..batch {
                    for t in 0..tpos {
                        let db = &ce.dlogits[(b * tpos + t) * VOCAB
                            ..(b * tpos + t + 1) * VOCAB];
                        let pos = b * seq + t;
                        let hv = &h[pos * e..(pos + 1) * e];
                        // aux W/b grads
                        for j in 0..e {
                            let row = &mut out
                                [nc + j * VOCAB..nc + (j + 1) * VOCAB];
                            for v in 0..VOCAB {
                                row[v] -= lr * hv[j] * db[v];
                            }
                        }
                        let boff = nc + e * VOCAB;
                        for v in 0..VOCAB {
                            out[boff + v] -= lr * db[v];
                        }
                        // ΔE grad through tanh'
                        let tok =
                            (x[pos].clamp(0, VOCAB as i32 - 1)) as usize;
                        for j in 0..e {
                            let row = &wa[j * VOCAB..(j + 1) * VOCAB];
                            let mut gh = 0.0f32;
                            for v in 0..VOCAB {
                                gh += db[v] * row[v];
                            }
                            let hj = hv[j];
                            out[tok * e + j] -= lr * gh * (1.0 - hj * hj);
                        }
                    }
                }
            }
            AuxKind::Mlp(_) => {
                // FO through the MLP aux is only exercised by the Fig 6
                // ablation; a plain SPSA-style fallback keeps it trainable
                // without a full hand-written backprop: reuse the ZO
                // estimator with a fixed probe count.
                self.zo_step_into(
                    base, theta_l, x, seq, 0x0F0E, 1e-2, lr, 4, out,
                );
            }
        }
        ce.mean as f32
    }

    /// FO step on θ_l (aux head + ΔE), sum reduction.
    pub fn fo_step(
        &self,
        base: &[f32],
        theta_l: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
    ) -> (Vec<f32>, f32) {
        let mut out = Vec::new();
        let loss = self.fo_step_into(base, theta_l, x, seq, lr, &mut out);
        (out, loss)
    }

    /// Server FO update (sum reduction) into reused buffers; returns the
    /// loss and fills `cut` with the cut gradient if given.
    pub fn server_step_into(
        &self,
        theta_s: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
        cut: Option<&mut Vec<f32>>,
        out: &mut Vec<f32>,
    ) -> f32 {
        let e = self.e;
        let ce = self.linear_head_ce(theta_s, smashed, x, seq);
        let tpos = seq - 1;
        let batch = x.len() / seq;
        out.clear();
        out.extend_from_slice(theta_s);
        for b in 0..batch {
            for t in 0..tpos {
                let db = &ce.dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                let pos = b * seq + t;
                let hv = &smashed[pos * e..(pos + 1) * e];
                for j in 0..e {
                    let row = &mut out[j * VOCAB..(j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        row[v] -= lr * hv[j] * db[v];
                    }
                }
                let boff = e * VOCAB;
                for v in 0..VOCAB {
                    out[boff + v] -= lr * db[v];
                }
            }
        }
        if let Some(g) = cut {
            let wm = &theta_s[..e * VOCAB];
            g.clear();
            g.resize(smashed.len(), 0.0);
            for b in 0..batch {
                for t in 0..tpos {
                    let db = &ce.dlogits[(b * tpos + t) * VOCAB
                        ..(b * tpos + t + 1) * VOCAB];
                    let pos = b * seq + t;
                    let gv = &mut g[pos * e..(pos + 1) * e];
                    for j in 0..e {
                        let row = &wm[j * VOCAB..(j + 1) * VOCAB];
                        let mut s = 0.0f32;
                        for v in 0..VOCAB {
                            s += db[v] * row[v];
                        }
                        gv[j] = s;
                    }
                }
            }
        }
        ce.mean as f32
    }

    /// Server FO update (sum reduction); optionally the cut gradient.
    pub fn server_step(
        &self,
        theta_s: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        lr: f32,
        want_cutgrad: bool,
    ) -> (Vec<f32>, f32, Option<Vec<f32>>) {
        let mut out = Vec::new();
        let mut cut = Vec::new();
        let loss = self.server_step_into(
            theta_s,
            smashed,
            x,
            seq,
            lr,
            if want_cutgrad { Some(&mut cut) } else { None },
            &mut out,
        );
        (out, loss, if want_cutgrad { Some(cut) } else { None })
    }

    /// Client backprop from the relayed cut gradient (SplitLoRA path).
    pub fn client_bp_step_into(
        &self,
        base: &[f32],
        theta_c: &[f32],
        x: &[i32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) {
        let e = self.e;
        let mut h = Vec::new();
        self.client_fwd_into(base, theta_c, x, &mut h);
        out.clear();
        out.extend_from_slice(theta_c);
        for (i, &tok) in x.iter().enumerate() {
            let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
            let hv = &h[i * e..(i + 1) * e];
            let gv = &g_smashed[i * e..(i + 1) * e];
            for j in 0..e {
                out[t * e + j] -= lr * gv[j] * (1.0 - hv[j] * hv[j]);
            }
        }
    }

    /// Client backprop from the relayed cut gradient (SplitLoRA path).
    pub fn client_bp_step(
        &self,
        base: &[f32],
        theta_c: &[f32],
        x: &[i32],
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.client_bp_step_into(base, theta_c, x, g_smashed, lr, &mut out);
        out
    }

    /// FSL-SAGE alignment of the aux head toward the server cut gradient.
    pub fn aux_align_into(
        &self,
        base: &[f32],
        theta_l: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) {
        let _ = base;
        let e = self.e;
        let nc = self.nc();
        out.clear();
        out.extend_from_slice(theta_l);
        if self.aux != AuxKind::Linear {
            // bias-only aux has no cut-gradient path to align; the MLP aux
            // alignment is not exercised by any configured baseline
            return;
        }
        let ce = self.aux_ce(&theta_l[nc..], smashed, x, seq);
        let wa = &theta_l[nc..nc + e * VOCAB];
        let tpos = seq - 1;
        let batch = x.len() / seq;
        for b in 0..batch {
            for t in 0..tpos {
                let db = &ce.dlogits
                    [(b * tpos + t) * VOCAB..(b * tpos + t + 1) * VOCAB];
                let pos = b * seq + t;
                let gs = &g_smashed[pos * e..(pos + 1) * e];
                for j in 0..e {
                    let row = &wa[j * VOCAB..(j + 1) * VOCAB];
                    let mut ga = 0.0f32;
                    for v in 0..VOCAB {
                        ga += db[v] * row[v];
                    }
                    let diff = ga - gs[j];
                    let orow =
                        &mut out[nc + j * VOCAB..nc + (j + 1) * VOCAB];
                    for v in 0..VOCAB {
                        orow[v] -= lr * diff * db[v];
                    }
                }
            }
        }
    }

    /// FSL-SAGE alignment of the aux head toward the server cut gradient.
    pub fn aux_align(
        &self,
        base: &[f32],
        theta_l: &[f32],
        smashed: &[f32],
        x: &[i32],
        seq: usize,
        g_smashed: &[f32],
        lr: f32,
    ) -> Vec<f32> {
        let mut out = Vec::new();
        self.aux_align_into(
            base, theta_l, smashed, x, seq, g_smashed, lr, &mut out,
        );
        out
    }

    /// (NLL sum, valid-token count) of the assembled client+server model.
    pub fn eval(
        &self,
        base: &[f32],
        theta_c: &[f32],
        theta_s: &[f32],
        x: &[i32],
        seq: usize,
    ) -> (f32, f32) {
        let h = self.client_fwd(base, theta_c, x);
        let out = self.linear_head_ce(theta_s, &h, x, seq);
        (out.sum as f32, out.count as f32)
    }
}

// ---------------------------------------------------------------------------
// typed runtime surface
// ---------------------------------------------------------------------------

/// The LM split model cannot run without its frozen base table.
fn req_base(base: Option<&[f32]>) -> Result<&[f32]> {
    base.context("lm runtime requires the frozen base blob")
}

impl ClientRuntime for LmModel {
    fn layout(&self) -> ThetaLayout {
        ThetaLayout {
            nc: self.nc(),
            na: self.na(),
            ns: self.ns(),
            nb: self.nc(),
        }
    }

    fn zo_step(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        zo: ZoArgs,
        out: &mut Vec<f32>,
        rec: &mut ZoStepRecord,
    ) -> Result<()> {
        let base = req_base(base)?;
        let x = x.as_i32()?;
        let _ = y; // LM targets are the shifted tokens inside x
        rec.seed = zo.seed;
        rec.gscales.clear();
        let gs = &mut rec.gscales;
        rec.loss = self.zo_step_probes_into(
            base,
            theta_l,
            x,
            self.seq,
            zo.seed,
            zo.mu,
            zo.lr,
            zo.n_pert,
            out,
            |g| gs.push(g),
        );
        Ok(())
    }

    fn fo_step(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<f32> {
        let _ = y;
        Ok(self.fo_step_into(
            req_base(base)?,
            theta_l,
            x.as_i32()?,
            self.seq,
            lr,
            out,
        ))
    }

    fn client_fwd(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.client_fwd_into(req_base(base)?, theta_c, x.as_i32()?, out);
        Ok(())
    }

    fn server_step(
        &self,
        _base: Option<&[f32]>,
        theta_s: &[f32],
        smashed: &[f32],
        y: &[i32],
        lr: f32,
        cut: Option<&mut Vec<f32>>,
        out: &mut Vec<f32>,
    ) -> Result<f32> {
        // y is the token batch (targets derived in-model by shifting)
        Ok(self.server_step_into(theta_s, smashed, y, self.seq, lr, cut, out))
    }

    fn client_bp_step(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        x: TensorRef<'_>,
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.client_bp_step_into(
            req_base(base)?,
            theta_c,
            x.as_i32()?,
            g_smashed,
            lr,
            out,
        );
        Ok(())
    }

    fn aux_align(
        &self,
        base: Option<&[f32]>,
        theta_l: &[f32],
        smashed: &[f32],
        y: &[i32],
        g_smashed: &[f32],
        lr: f32,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        self.aux_align_into(
            req_base(base)?,
            theta_l,
            smashed,
            y,
            self.seq,
            g_smashed,
            lr,
            out,
        );
        Ok(())
    }

    fn eval_full(
        &self,
        base: Option<&[f32]>,
        theta_c: &[f32],
        theta_s: &[f32],
        x: TensorRef<'_>,
        y: &[i32],
    ) -> Result<(f32, f32)> {
        let _ = y;
        Ok(self.eval(
            req_base(base)?,
            theta_c,
            theta_s,
            x.as_i32()?,
            self.seq,
        ))
    }
}

/// (nll, softmax probs) for one logits row and target index.
fn log_softmax_nll(logits: &[f32], target: usize) -> (f32, Vec<f32>) {
    let mut mx = f32::NEG_INFINITY;
    for &v in logits {
        mx = mx.max(v);
    }
    let mut se = 0.0f32;
    for &v in logits {
        se += (v - mx).exp();
    }
    let lse = mx + se.ln();
    let probs: Vec<f32> = logits.iter().map(|&v| (v - lse).exp()).collect();
    (lse - logits[target.min(logits.len() - 1)], probs)
}

/// The nll of [`log_softmax_nll`] without materializing the probs — the
/// same max/sum-exp/ln op sequence, hence the same bits.
fn nll_only(logits: &[f32], target: usize) -> f32 {
    let mut mx = f32::NEG_INFINITY;
    for &v in logits {
        mx = mx.max(v);
    }
    let mut se = 0.0f32;
    for &v in logits {
        se += (v - mx).exp();
    }
    let lse = mx + se.ln();
    lse - logits[target.min(logits.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_text;
    use crate::zo::stream::{fold_seed, PerturbStream};

    const SEQ: usize = synth_text::SEQ_LEN;

    fn base(e: usize) -> Vec<f32> {
        PerturbStream::new(fold_seed(0xBA5E, 1))
            .take_vec(VOCAB * e)
            .into_iter()
            .map(|v| v * 0.3)
            .collect()
    }

    fn model() -> LmModel {
        LmModel::new(16, AuxKind::Linear, SEQ)
    }

    #[test]
    fn uniform_head_gives_log_vocab_nll() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let th_c = vec![0.0f32; m.nc()];
        let ts = vec![0.0f32; m.ns()];
        let (nll, n) = m.eval(&b, &th_c, &ts, &x, SEQ);
        let per_tok = nll / n;
        assert!(
            (per_tok - (VOCAB as f32).ln()).abs() < 1e-3,
            "uniform ppl should be vocab-sized: per-token nll {per_tok}"
        );
    }

    #[test]
    fn server_steps_reduce_nll() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let th_c = vec![0.0f32; m.nc()];
        let h = m.client_fwd(&b, &th_c, &x);
        let mut ts = vec![0.0f32; m.ns()];
        let (_, l0, _) = m.server_step(&ts, &h, &x, SEQ, 0.0, false);
        for _ in 0..4 {
            ts = m.server_step(&ts, &h, &x, SEQ, 1e-3, false).0;
        }
        let (_, l1, _) = m.server_step(&ts, &h, &x, SEQ, 0.0, false);
        assert!(l1 < l0 * 0.97, "server NLL {l0} -> {l1}");
    }

    #[test]
    fn zo_step_deterministic() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 2);
        let th = vec![0.0f32; m.nl()];
        let (a, la) = m.zo_step(&b, &th, &x, SEQ, 42, 1e-2, 1e-3, 1);
        let (bb, lb) = m.zo_step(&b, &th, &x, SEQ, 42, 1e-2, 1e-3, 1);
        assert_eq!(a, bb);
        assert_eq!(la, lb);
        assert!((la - (VOCAB as f32).ln()).abs() < 0.05);
    }

    #[test]
    fn chunked_zo_matches_materialized_reference() {
        // reference: the pre-refactor formulation with a materialized u
        // per probe and a separate delta vector
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 2);
        let th: Vec<f32> = PerturbStream::new(fold_seed(0x7E57, 2))
            .take_vec(m.nl())
            .into_iter()
            .map(|v| v * 0.05)
            .collect();
        let d = th.len();
        let (seed, mu, lr, n_pert) = (0x5EED, 1e-2f32, 1e-3f32, 3usize);
        let lbase = m.local_loss(&b, &th, &x, SEQ);
        let mut delta = vec![0.0f32; d];
        let mut pert = vec![0.0f32; d];
        for k in 0..n_pert {
            let u = PerturbStream::new(fold_seed(seed as u32, k as u32))
                .take_vec(d);
            for i in 0..d {
                pert[i] = th[i] + mu * u[i];
            }
            let lp = m.local_loss(&b, &pert, &x, SEQ);
            let gscale = (lp - lbase) / mu * (lr / n_pert as f32);
            for i in 0..d {
                delta[i] -= gscale * u[i];
            }
        }
        let mut want = th.clone();
        for i in 0..d {
            want[i] += delta[i];
        }
        let (got, lgot) =
            m.zo_step(&b, &th, &x, SEQ, seed, mu, lr, n_pert as i32);
        assert_eq!(lgot.to_bits(), lbase.to_bits());
        for i in 0..d {
            assert_eq!(got[i].to_bits(), want[i].to_bits(), "elem {i}");
        }
    }

    #[test]
    fn zo_probe_record_replays_bitwise() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 2);
        let th: Vec<f32> = PerturbStream::new(fold_seed(0x7E57, 4))
            .take_vec(m.nl())
            .into_iter()
            .map(|v| v * 0.05)
            .collect();
        let (seed, mu, lr, np) = (0x1EAF, 1e-2f32, 1e-3f32, 2i32);
        let mut out = Vec::new();
        let mut gs = Vec::new();
        let lbase = m.zo_step_probes_into(
            &b, &th, &x, SEQ, seed, mu, lr, np, &mut out, |g| gs.push(g),
        );
        let (want, lwant) = m.zo_step(&b, &th, &x, SEQ, seed, mu, lr, np);
        assert_eq!(lbase.to_bits(), lwant.to_bits());
        assert_eq!(out, want);
        assert_eq!(gs.len(), np as usize);
        let mut replayed = Vec::new();
        crate::zo::stream::replay_update(&th, seed, &gs, &mut replayed);
        assert_eq!(replayed, want);
        // typed trait surface: same step, same record
        let mut rec = ZoStepRecord::default();
        let mut tout = Vec::new();
        ClientRuntime::zo_step(
            &m,
            Some(&b),
            &th,
            TensorRef::I32(&x),
            &x,
            ZoArgs { seed, mu, lr, n_pert: np },
            &mut tout,
            &mut rec,
        )
        .unwrap();
        assert_eq!(tout, want);
        assert_eq!(rec.gscales, gs);
        // the base blob is not optional for the LM runtime
        assert!(ClientRuntime::zo_step(
            &m,
            None,
            &th,
            TensorRef::I32(&x),
            &x,
            ZoArgs { seed, mu, lr, n_pert: np },
            &mut tout,
            &mut rec,
        )
        .is_err());
    }

    #[test]
    fn aux_loss_matches_aux_ce_mean_for_all_kinds() {
        for aux in [AuxKind::Bias, AuxKind::Linear, AuxKind::Mlp(8)] {
            let m = LmModel::new(16, aux, SEQ);
            let b = base(16);
            let x = synth_text::batch(7, 0, 2);
            let wa: Vec<f32> = PerturbStream::new(fold_seed(0xA0A, 3))
                .take_vec(m.na())
                .into_iter()
                .map(|v| v * 0.1)
                .collect();
            let th_c = vec![0.0f32; m.nc()];
            let h = m.client_fwd(&b, &th_c, &x);
            let want = m.aux_ce(&wa, &h, &x, SEQ).mean as f32;
            let mut logits = vec![0.0f32; VOCAB];
            let mut z1 = vec![0.0f32; m.aux_hidden()];
            let got = m.aux_loss(&wa, &h, &x, SEQ, &mut logits, &mut z1);
            assert_eq!(got.to_bits(), want.to_bits(), "aux {aux:?}");
        }
    }

    #[test]
    fn cached_base_rows_leave_fwd_bit_identical() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(11, 0, 2);
        let th_c: Vec<f32> = PerturbStream::new(fold_seed(0xC0DE, 1))
            .take_vec(m.nc())
            .into_iter()
            .map(|v| v * 0.05)
            .collect();
        // direct reference without the gather cache
        let e = m.e;
        let mut want = vec![0.0f32; x.len() * e];
        for (i, &tok) in x.iter().enumerate() {
            let t = (tok.clamp(0, VOCAB as i32 - 1)) as usize;
            for j in 0..e {
                want[i * e + j] = (b[t * e + j] + th_c[t * e + j]).tanh();
            }
        }
        let h1 = m.client_fwd(&b, &th_c, &x); // cold: gather miss
        let h2 = m.client_fwd(&b, &th_c, &x); // warm: gather hit
        assert_eq!(h1, want);
        assert_eq!(h2, want);
        let st = m.cache_stats();
        assert!(st.hits >= 1 && st.misses >= 1);
    }

    #[test]
    fn fo_step_descends_on_linear_aux() {
        let m = model();
        let b = base(16);
        let x = synth_text::batch(42, 0, 4);
        let mut th = vec![0.0f32; m.nl()];
        let l0 = m.local_loss(&b, &th, &x, SEQ);
        for _ in 0..4 {
            th = m.fo_step(&b, &th, &x, SEQ, 1e-3).0;
        }
        let l1 = m.local_loss(&b, &th, &x, SEQ);
        assert!(l1 < l0 * 0.99, "aux NLL {l0} -> {l1}");
    }

    #[test]
    fn aux_sizes_per_kind() {
        assert_eq!(AuxKind::Bias.size(16), 96);
        assert_eq!(AuxKind::Linear.size(16), 16 * 96 + 96);
        assert_eq!(AuxKind::Mlp(8).size(16), 16 * 8 + 8 + 8 * 96 + 96);
    }

    #[test]
    fn pad_targets_are_masked() {
        let m = model();
        let b = base(16);
        // one real record (has trailing PADs) — count must be < seq-1
        let x = synth_text::batch(42, 0, 1);
        let th_c = vec![0.0f32; m.nc()];
        let ts = vec![0.0f32; m.ns()];
        let (_, n) = m.eval(&b, &th_c, &ts, &x, SEQ);
        assert!(n > 10.0 && n < (SEQ - 1) as f32);
    }
}
