//! Feature-plan cache for the native engine's θ-independent projections.
//!
//! The HERON client hot loop invokes several entries against the *same*
//! input batch (h local steps, the upload `client_fwd`, repeated eval
//! batches). The expensive part of each vision invocation — the Gabor
//! feature-bank projection — and the LM base-row gather depend only on the
//! input batch, never on θ, so the engine memoizes them here keyed by a
//! content hash of the batch.
//!
//! Correctness: cached values are produced by the exact same code path as
//! uncached ones, so a hit returns bit-identical data; the cache can only
//! change *when* a projection is computed, never *what* it contains. The
//! map is sharded by key (one mutex per shard) so concurrent worker
//! threads rarely contend, and each shard clears itself when it exceeds
//! its byte budget — a bounded, allocation-stable steady state.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const DEFAULT_SHARDS: usize = 8;
/// Per-shard value-byte budget (~16 MiB total at 8 shards).
const DEFAULT_SHARD_BYTES: usize = 2 << 20;

#[derive(Debug, Default, Clone, Copy)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Bytes served from cache instead of being recomputed + reallocated.
    pub bytes_avoided: u64,
}

struct Shard {
    map: HashMap<u128, Arc<Vec<f32>>>,
    bytes: usize,
}

pub struct FeatureCache {
    shards: Vec<Mutex<Shard>>,
    shard_byte_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    bytes_avoided: AtomicU64,
}

impl FeatureCache {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_SHARDS, DEFAULT_SHARD_BYTES)
    }

    pub fn with_capacity(shards: usize, shard_byte_cap: usize) -> Self {
        let shards = shards.max(1);
        FeatureCache {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        bytes: 0,
                    })
                })
                .collect(),
            shard_byte_cap: shard_byte_cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            bytes_avoided: AtomicU64::new(0),
        }
    }

    /// Return the cached value for `key`, computing and inserting it on a
    /// miss. `compute` runs outside the shard lock, so a slow projection
    /// never blocks other shards (a rare duplicate computation under a
    /// race produces bit-identical data and is harmless).
    pub fn get_or_compute(
        &self,
        key: u128,
        compute: impl FnOnce() -> Vec<f32>,
    ) -> Arc<Vec<f32>> {
        let shard = &self.shards
            [((key >> 64) as u64 % self.shards.len() as u64) as usize];
        {
            let guard = shard.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(v) = guard.map.get(&key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.bytes_avoided
                    .fetch_add((v.len() * 4) as u64, Ordering::Relaxed);
                return v.clone();
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let value = Arc::new(compute());
        let sz = value.len() * 4;
        let mut guard = shard.lock().unwrap_or_else(|p| p.into_inner());
        if guard.bytes + sz > self.shard_byte_cap {
            guard.map.clear();
            guard.bytes = 0;
        }
        // a racing thread may have inserted while we computed: keep the
        // resident value (bit-identical anyway) and don't double-count
        // its bytes
        if let Some(existing) = guard.map.get(&key) {
            return existing.clone();
        }
        guard.map.insert(key, value.clone());
        guard.bytes += sz;
        value
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            bytes_avoided: self.bytes_avoided.load(Ordering::Relaxed),
        }
    }
}

impl Default for FeatureCache {
    fn default() -> Self {
        Self::new()
    }
}

const FNV_OFFSET: u64 = 0xCBF2_9CE4_8422_2325;
const FNV_PRIME: u64 = 0x0100_0000_01B3;
const MIX_SEED: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX_MUL: u64 = 0xFF51_AFD7_ED55_8CCD;

/// 128-bit content key: two independent 64-bit accumulators (FNV-1a and a
/// murmur-style multiply-rotate mix) folded over the words in one pass.
/// Two batches must collide in *both* lanes to alias, which makes the
/// no-verify-on-hit cache safe against the batch populations this crate
/// sees (collision odds ~2^-128-ish, vs the uncomfortably structured
/// 2^-64 of a single FNV lane).
#[inline]
fn hash_words(seed: u64, words: impl Iterator<Item = u64>, len: usize) -> u128 {
    let mut h1 = (seed ^ FNV_OFFSET).wrapping_mul(FNV_PRIME);
    let mut h2 = seed.wrapping_add(MIX_SEED);
    for w in words {
        h1 = (h1 ^ w).wrapping_mul(FNV_PRIME);
        h2 = (h2 ^ w).wrapping_mul(MIX_MUL).rotate_left(31);
    }
    h1 ^= len as u64;
    h2 ^= (len as u64).rotate_left(32);
    ((h1 as u128) << 64) | h2 as u128
}

/// 128-bit content hash over the f32 bit patterns (stable across runs).
pub fn hash_f32(seed: u64, xs: &[f32]) -> u128 {
    hash_words(seed, xs.iter().map(|x| x.to_bits() as u64), xs.len())
}

/// 128-bit content hash over an i32 batch (token streams).
pub fn hash_i32(seed: u64, xs: &[i32]) -> u128 {
    hash_words(seed, xs.iter().map(|&x| x as u32 as u64), xs.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_returns_same_value_and_counts() {
        let c = FeatureCache::new();
        let k = hash_f32(1, &[1.0, 2.0]);
        let a = c.get_or_compute(k, || vec![3.0, 4.0]);
        let b = c.get_or_compute(k, || panic!("must not recompute"));
        assert_eq!(&*a, &*b);
        let st = c.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 1);
        assert_eq!(st.bytes_avoided, 8);
    }

    #[test]
    fn distinct_inputs_distinct_keys() {
        assert_ne!(hash_f32(0, &[1.0, 2.0]), hash_f32(0, &[2.0, 1.0]));
        assert_ne!(hash_f32(0, &[0.0]), hash_f32(0, &[0.0, 0.0]));
        assert_ne!(hash_i32(0, &[5, 6]), hash_i32(0, &[6, 5]));
        assert_ne!(hash_f32(7, &[1.0]), hash_f32(8, &[1.0]));
    }

    #[test]
    fn byte_cap_bounds_resident_size() {
        let c = FeatureCache::with_capacity(1, 64);
        for i in 0..100u128 {
            c.get_or_compute(i, || vec![0.0; 8]); // 32 bytes each
        }
        let shard = c.shards[0].lock().unwrap();
        assert!(shard.bytes <= 64 + 32, "resident {} bytes", shard.bytes);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let c = FeatureCache::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..64u128 {
                        let v = c.get_or_compute(i, || vec![i as f32; 4]);
                        assert_eq!(v[0], i as f32);
                    }
                });
            }
        });
        let st = c.stats();
        assert_eq!(st.hits + st.misses, 256);
    }
}
