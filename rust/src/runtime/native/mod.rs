//! Native reference execution engine (substrate S20).
//!
//! Executes the manifest's entry points with pure, deterministic Rust —
//! the same split-model semantics the AOT HLO artifacts implement, with a
//! fixed f32 evaluation order so results are bit-identical across runs,
//! thread counts, and scheduling orders. This is the default backend; a
//! PJRT-backed session can slot in behind the same [`crate::runtime::Session`]
//! API when the XLA toolchain is available (it is not part of the offline
//! vendor set).
//!
//! The engine is stateless per call and `Sync`: every model's fixed state
//! (the vision feature banks) is built once at session construction, so
//! worker threads can invoke entries concurrently with no locking on the
//! hot path.

pub mod lm;
pub mod vision;

use crate::runtime::manifest::{EntrySpec, Manifest, VariantSpec};
use crate::runtime::tensor::TensorValue;
use anyhow::{bail, Context, Result};
use lm::{AuxKind, LmModel};
use std::collections::{BTreeMap, HashMap};
use vision::VisionModel;

pub enum Model {
    Vision(VisionModel),
    Lm(LmModel),
}

pub struct Engine {
    models: BTreeMap<String, Model>,
}

impl Engine {
    /// Build per-variant models from the manifest's size contract.
    pub fn new(manifest: &Manifest) -> Result<Self> {
        let mut models = BTreeMap::new();
        for (name, v) in &manifest.variants {
            models.insert(name.clone(), build_model(v)?);
        }
        Ok(Engine { models })
    }

    pub fn model(&self, variant: &str) -> Result<&Model> {
        self.models
            .get(variant)
            .with_context(|| format!("no native model for variant {variant}"))
    }

    /// Execute one entry. Inputs are positional per `espec.inputs`; outputs
    /// are returned positional per `espec.outputs`.
    pub fn execute(
        &self,
        vspec: &VariantSpec,
        espec: &EntrySpec,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let model = self.model(&vspec.name)?;
        let args: HashMap<&str, &TensorValue> = espec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(s, v)| (s.name.as_str(), v))
            .collect();
        let mut outs = match model {
            Model::Vision(m) => exec_vision(m, &espec.name, &args)?,
            Model::Lm(m) => exec_lm(m, vspec, &espec.name, &args)?,
        };
        let mut ordered = Vec::with_capacity(espec.outputs.len());
        for spec in &espec.outputs {
            let v = outs.remove(spec.name.as_str()).with_context(|| {
                format!("{}/{}: engine missing output {}", vspec.name, espec.name, spec.name)
            })?;
            ordered.push(v);
        }
        Ok(ordered)
    }
}

fn build_model(v: &VariantSpec) -> Result<Model> {
    if v.task == "vision" {
        let q = v.size_client / 2;
        if q == 0 || v.size_client != 2 * q {
            bail!("variant {}: bad vision client size {}", v.name, v.size_client);
        }
        Ok(Model::Vision(VisionModel::new(q)))
    } else {
        let e = v.size_client / lm::VOCAB;
        if e == 0 || v.size_client != e * lm::VOCAB {
            bail!("variant {}: bad lm client size {}", v.name, v.size_client);
        }
        let aux = if v.size_aux == AuxKind::Bias.size(e) {
            AuxKind::Bias
        } else if v.size_aux == AuxKind::Linear.size(e) {
            AuxKind::Linear
        } else {
            // size_aux = e*k + k + k*96 + 96  =>  k = (size_aux-96)/(e+97)
            let k = (v.size_aux - lm::VOCAB) / (e + lm::VOCAB + 1);
            if AuxKind::Mlp(k).size(e) != v.size_aux {
                bail!("variant {}: unresolvable aux size {}", v.name, v.size_aux);
            }
            AuxKind::Mlp(k)
        };
        Ok(Model::Lm(LmModel::new(e, aux)))
    }
}

fn f32_arg<'a>(
    args: &'a HashMap<&str, &TensorValue>,
    name: &str,
) -> Result<&'a [f32]> {
    args.get(name)
        .with_context(|| format!("missing input {name}"))?
        .as_f32()
}

fn i32_arg<'a>(
    args: &'a HashMap<&str, &TensorValue>,
    name: &str,
) -> Result<&'a [i32]> {
    match args.get(name).with_context(|| format!("missing input {name}"))? {
        TensorValue::I32(v) => Ok(v),
        other => bail!("input {name}: expected i32, got {:?}", other.dtype()),
    }
}

fn scalar_f32(args: &HashMap<&str, &TensorValue>, name: &str) -> Result<f32> {
    args.get(name)
        .with_context(|| format!("missing input {name}"))?
        .scalar_f32()
}

fn scalar_i32(args: &HashMap<&str, &TensorValue>, name: &str) -> Result<i32> {
    match args.get(name).with_context(|| format!("missing input {name}"))? {
        TensorValue::ScalarI32(s) => Ok(*s),
        TensorValue::I32(v) if v.len() == 1 => Ok(v[0]),
        other => bail!("input {name}: expected i32 scalar, got len {}", other.len()),
    }
}

fn exec_vision(
    m: &VisionModel,
    entry: &str,
    args: &HashMap<&str, &TensorValue>,
) -> Result<HashMap<&'static str, TensorValue>> {
    let mut outs: HashMap<&'static str, TensorValue> = HashMap::new();
    match entry {
        "local_loss" => {
            let loss = m.local_loss(
                f32_arg(args, "theta_l")?,
                f32_arg(args, "x")?,
                i32_arg(args, "y")?,
            );
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "zo_step" => {
            let (theta, loss) = m.zo_step(
                f32_arg(args, "theta_l")?,
                f32_arg(args, "x")?,
                i32_arg(args, "y")?,
                scalar_i32(args, "seed")?,
                scalar_f32(args, "mu")?,
                scalar_f32(args, "lr")?,
                scalar_i32(args, "n_pert")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "fo_step" => {
            let (theta, loss) = m.fo_step(
                f32_arg(args, "theta_l")?,
                f32_arg(args, "x")?,
                i32_arg(args, "y")?,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "client_fwd" => {
            let smashed =
                m.client_fwd(f32_arg(args, "theta_c")?, f32_arg(args, "x")?);
            outs.insert("smashed", TensorValue::F32(smashed));
        }
        "server_step" | "server_step_cutgrad" => {
            let want = entry == "server_step_cutgrad";
            let (theta, loss, cut) = m.server_step(
                f32_arg(args, "theta_s")?,
                f32_arg(args, "smashed")?,
                i32_arg(args, "y")?,
                scalar_f32(args, "lr")?,
                want,
            );
            outs.insert("theta_s", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
            if let Some(g) = cut {
                outs.insert("g_smashed", TensorValue::F32(g));
            }
        }
        "client_bp_step" => {
            let theta = m.client_bp_step(
                f32_arg(args, "theta_c")?,
                f32_arg(args, "x")?,
                f32_arg(args, "g_smashed")?,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_c", TensorValue::F32(theta));
        }
        "aux_align" => {
            let theta = m.aux_align(
                f32_arg(args, "theta_l")?,
                f32_arg(args, "smashed")?,
                i32_arg(args, "y")?,
                f32_arg(args, "g_smashed")?,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
        }
        "eval_full" => {
            let (s1, s2) = m.eval(
                f32_arg(args, "theta_c")?,
                f32_arg(args, "theta_s")?,
                f32_arg(args, "x")?,
                i32_arg(args, "y")?,
            );
            outs.insert("stat1", TensorValue::ScalarF32(s1));
            outs.insert("stat2", TensorValue::ScalarF32(s2));
        }
        "hvp" => {
            let hv = m.hvp(
                f32_arg(args, "theta_l")?,
                f32_arg(args, "x")?,
                i32_arg(args, "y")?,
                f32_arg(args, "v")?,
            );
            outs.insert("hv", TensorValue::F32(hv));
        }
        other => bail!("vision model has no entry {other}"),
    }
    Ok(outs)
}

fn exec_lm(
    m: &LmModel,
    vspec: &VariantSpec,
    entry: &str,
    args: &HashMap<&str, &TensorValue>,
) -> Result<HashMap<&'static str, TensorValue>> {
    let seq: usize = vspec.x_shape.iter().product::<usize>().max(1);
    let base = f32_arg(args, "base")?;
    let mut outs: HashMap<&'static str, TensorValue> = HashMap::new();
    match entry {
        "local_loss" => {
            let loss = m.local_loss(
                base,
                f32_arg(args, "theta_l")?,
                i32_arg(args, "x")?,
                seq,
            );
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "zo_step" => {
            let (theta, loss) = m.zo_step(
                base,
                f32_arg(args, "theta_l")?,
                i32_arg(args, "x")?,
                seq,
                scalar_i32(args, "seed")?,
                scalar_f32(args, "mu")?,
                scalar_f32(args, "lr")?,
                scalar_i32(args, "n_pert")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "fo_step" => {
            let (theta, loss) = m.fo_step(
                base,
                f32_arg(args, "theta_l")?,
                i32_arg(args, "x")?,
                seq,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
        }
        "client_fwd" => {
            let smashed = m.client_fwd(
                base,
                f32_arg(args, "theta_c")?,
                i32_arg(args, "x")?,
            );
            outs.insert("smashed", TensorValue::F32(smashed));
        }
        "server_step" | "server_step_cutgrad" => {
            let want = entry == "server_step_cutgrad";
            let (theta, loss, cut) = m.server_step(
                f32_arg(args, "theta_s")?,
                f32_arg(args, "smashed")?,
                i32_arg(args, "y")?,
                seq,
                scalar_f32(args, "lr")?,
                want,
            );
            outs.insert("theta_s", TensorValue::F32(theta));
            outs.insert("loss", TensorValue::ScalarF32(loss));
            if let Some(g) = cut {
                outs.insert("g_smashed", TensorValue::F32(g));
            }
        }
        "client_bp_step" => {
            let theta = m.client_bp_step(
                base,
                f32_arg(args, "theta_c")?,
                i32_arg(args, "x")?,
                f32_arg(args, "g_smashed")?,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_c", TensorValue::F32(theta));
        }
        "aux_align" => {
            // round driver sends the token batch as `y` for LM tasks
            let theta = m.aux_align(
                base,
                f32_arg(args, "theta_l")?,
                f32_arg(args, "smashed")?,
                i32_arg(args, "y")?,
                seq,
                f32_arg(args, "g_smashed")?,
                scalar_f32(args, "lr")?,
            );
            outs.insert("theta_l", TensorValue::F32(theta));
        }
        "eval_full" => {
            let (s1, s2) = m.eval(
                base,
                f32_arg(args, "theta_c")?,
                f32_arg(args, "theta_s")?,
                i32_arg(args, "x")?,
                seq,
            );
            outs.insert("stat1", TensorValue::ScalarF32(s1));
            outs.insert("stat2", TensorValue::ScalarF32(s2));
        }
        other => bail!("lm model has no entry {other}"),
    }
    Ok(outs)
}
