//! Native reference execution engine (substrate S20).
//!
//! Executes the manifest's entry points with pure, deterministic Rust —
//! the same split-model semantics the AOT HLO artifacts implement, with a
//! fixed f32 evaluation order so results are bit-identical across runs,
//! thread counts, and scheduling orders. This is the default backend; a
//! PJRT-backed session can slot in behind the same [`crate::runtime::Session`]
//! API when the XLA toolchain is available (it is not part of the offline
//! vendor set).
//!
//! The engine is `Sync`: every model's fixed state (the vision feature
//! banks) is built once at session construction, and the per-model
//! [`cache::FeatureCache`] of θ-independent projections is sharded behind
//! its own locks, so worker threads invoke entries concurrently with no
//! contention on the compute path.
//!
//! ## Zero-allocation execution
//!
//! [`Engine::execute_into`] is the primary path: inputs arrive as borrowed
//! [`TensorRef`] views (no argument cloning) and outputs are written into
//! a caller-owned `Vec<TensorValue>` whose buffers are reused across
//! invocations. The allocating [`Engine::execute`] wrapper remains for
//! cold paths and produces bit-identical results.

pub mod cache;
pub mod lm;
pub mod vision;

use crate::runtime::manifest::{EntrySpec, Manifest, VariantSpec};
use crate::runtime::tensor::{TensorRef, TensorValue};
use anyhow::{bail, Context, Result};
use cache::CacheStats;
use lm::{AuxKind, LmModel};
use std::collections::BTreeMap;
use vision::VisionModel;

pub enum Model {
    Vision(VisionModel),
    Lm(LmModel),
}

pub struct Engine {
    models: BTreeMap<String, Model>,
}

impl Engine {
    /// Build per-variant models from the manifest's size contract, after
    /// validating every declared entry against the typed API's canonical
    /// signatures ([`crate::runtime::api::ENTRY_SIGS`]). A drifted
    /// manifest — an unknown entry, a stale/renamed/reordered tensor —
    /// fails here, at session construction, instead of producing a
    /// stale-slot hazard (or a late bail) at first invoke.
    pub fn new(manifest: &Manifest) -> Result<Self> {
        let mut models = BTreeMap::new();
        for (name, v) in &manifest.variants {
            for espec in v.entries.values() {
                crate::runtime::api::check_entry_spec(name, espec)?;
            }
            models.insert(name.clone(), build_model(v)?);
        }
        Ok(Engine { models })
    }

    pub fn model(&self, variant: &str) -> Result<&Model> {
        self.models
            .get(variant)
            .with_context(|| format!("no native model for variant {variant}"))
    }

    /// Aggregate feature-plan cache counters across all variant models.
    pub fn cache_stats(&self) -> CacheStats {
        let mut agg = CacheStats::default();
        for m in self.models.values() {
            let s = match m {
                Model::Vision(v) => v.cache_stats(),
                Model::Lm(l) => l.cache_stats(),
            };
            agg.hits += s.hits;
            agg.misses += s.misses;
            agg.bytes_avoided += s.bytes_avoided;
        }
        agg
    }

    /// Execute one entry (allocating wrapper). Inputs are positional per
    /// `espec.inputs`; outputs are returned positional per `espec.outputs`.
    pub fn execute(
        &self,
        vspec: &VariantSpec,
        espec: &EntrySpec,
        inputs: &[TensorValue],
    ) -> Result<Vec<TensorValue>> {
        let refs: Vec<TensorRef> =
            inputs.iter().map(|v| v.view()).collect();
        let mut outs = Vec::new();
        self.execute_into(vspec, espec, &refs, &mut outs)?;
        Ok(outs)
    }

    /// Execute one entry with borrowed inputs, writing outputs into
    /// `outs` (positional per `espec.outputs`, buffers reused when the
    /// slot already holds a vector). Bit-identical to [`Self::execute`].
    pub fn execute_into(
        &self,
        vspec: &VariantSpec,
        espec: &EntrySpec,
        inputs: &[TensorRef<'_>],
        outs: &mut Vec<TensorValue>,
    ) -> Result<()> {
        let model = self.model(&vspec.name)?;
        // the exec arms write a fixed set of named outputs; an entry spec
        // declaring more must fail loudly here, not silently hand back
        // placeholder (or previously-reused) slots
        if let Some(n) = produced_outputs(&espec.name) {
            if espec.outputs.len() != n {
                bail!(
                    "{}/{}: manifest declares {} outputs but the native \
                     engine produces {n}",
                    vspec.name,
                    espec.name,
                    espec.outputs.len()
                );
            }
        }
        prepare_outs(espec, outs);
        match model {
            Model::Vision(m) => exec_vision(m, espec, inputs, outs),
            Model::Lm(m) => exec_lm(m, vspec, espec, inputs, outs),
        }
    }
}

/// How many outputs the engine writes for each known entry (`None` for
/// unknown names — the exec arms reject those themselves). Derived from
/// the typed API's signature table, the same source `Engine::new` uses
/// to validate the manifest — the per-invoke guard and the construction
/// check can never disagree. `artifacts::tests` asserts the table covers
/// every generated entry spec.
pub(crate) fn produced_outputs(entry: &str) -> Option<usize> {
    crate::runtime::api::entry_sig(entry).map(|s| s.outputs.len())
}

fn build_model(v: &VariantSpec) -> Result<Model> {
    if v.task == "vision" {
        let q = v.size_client / 2;
        if q == 0 || v.size_client != 2 * q {
            bail!("variant {}: bad vision client size {}", v.name, v.size_client);
        }
        Ok(Model::Vision(VisionModel::new(q)))
    } else {
        let e = v.size_client / lm::VOCAB;
        if e == 0 || v.size_client != e * lm::VOCAB {
            bail!("variant {}: bad lm client size {}", v.name, v.size_client);
        }
        let seq: usize = v.x_shape.iter().product::<usize>().max(1);
        let aux = if v.size_aux == AuxKind::Bias.size(e) {
            AuxKind::Bias
        } else if v.size_aux == AuxKind::Linear.size(e) {
            AuxKind::Linear
        } else {
            // size_aux = e*k + k + k*96 + 96  =>  k = (size_aux-96)/(e+97)
            let k = (v.size_aux - lm::VOCAB) / (e + lm::VOCAB + 1);
            if AuxKind::Mlp(k).size(e) != v.size_aux {
                bail!("variant {}: unresolvable aux size {}", v.name, v.size_aux);
            }
            AuxKind::Mlp(k)
        };
        Ok(Model::Lm(LmModel::new(e, aux, seq)))
    }
}

// ---------------------------------------------------------------------------
// positional argument access (no marshalling maps on the hot path)
// ---------------------------------------------------------------------------

fn arg<'a>(
    espec: &EntrySpec,
    inputs: &[TensorRef<'a>],
    name: &str,
) -> Result<TensorRef<'a>> {
    for (spec, val) in espec.inputs.iter().zip(inputs) {
        if spec.name == name {
            return Ok(*val);
        }
    }
    bail!("missing input {name}")
}

fn f32_arg<'a>(
    espec: &EntrySpec,
    inputs: &[TensorRef<'a>],
    name: &str,
) -> Result<&'a [f32]> {
    arg(espec, inputs, name)?.as_f32()
}

fn i32_arg<'a>(
    espec: &EntrySpec,
    inputs: &[TensorRef<'a>],
    name: &str,
) -> Result<&'a [i32]> {
    arg(espec, inputs, name)?.as_i32()
}

fn scalar_f32(
    espec: &EntrySpec,
    inputs: &[TensorRef<'_>],
    name: &str,
) -> Result<f32> {
    arg(espec, inputs, name)?.scalar_f32()
}

fn scalar_i32(
    espec: &EntrySpec,
    inputs: &[TensorRef<'_>],
    name: &str,
) -> Result<i32> {
    arg(espec, inputs, name)?.scalar_i32()
}

// ---------------------------------------------------------------------------
// output slots (buffer-reusing)
// ---------------------------------------------------------------------------

/// Normalize `outs` to the entry's output arity, keeping any reusable
/// buffers already present in the slots.
fn prepare_outs(espec: &EntrySpec, outs: &mut Vec<TensorValue>) {
    outs.truncate(espec.outputs.len());
    while outs.len() < espec.outputs.len() {
        outs.push(TensorValue::ScalarF32(0.0));
    }
}

/// Borrow the f32 vector behind an output slot, converting the slot in
/// place if it held something else. The callee sizes and fills it.
fn out_f32_vec(outs: &mut [TensorValue], idx: usize) -> &mut Vec<f32> {
    if !matches!(outs[idx], TensorValue::F32(_)) {
        outs[idx] = TensorValue::F32(Vec::new());
    }
    match &mut outs[idx] {
        TensorValue::F32(v) => v,
        _ => unreachable!("slot was just normalized to F32"),
    }
}

/// Move the f32 vector out of a slot (leaving a scalar placeholder) so two
/// vector outputs can be filled without aliasing the slot array.
fn take_f32_buf(outs: &mut [TensorValue], idx: usize) -> Vec<f32> {
    match std::mem::replace(&mut outs[idx], TensorValue::ScalarF32(0.0)) {
        TensorValue::F32(v) => v,
        _ => Vec::new(),
    }
}

fn set_scalar_f32(outs: &mut [TensorValue], idx: usize, v: f32) {
    outs[idx] = TensorValue::ScalarF32(v);
}

/// The server_step / server_step_cutgrad slot choreography shared by both
/// tasks: resolve the θ_s/loss/(g_smashed) slots, lend the callee a cut
/// buffer taken from its slot when the entry wants one, write everything
/// back. `step(cut, theta_out)` returns the loss.
fn run_server_step(
    espec: &EntrySpec,
    outs: &mut Vec<TensorValue>,
    step: impl FnOnce(Option<&mut Vec<f32>>, &mut Vec<f32>) -> f32,
) -> Result<()> {
    let want = espec.name == "server_step_cutgrad";
    let ti = espec.output_pos("theta_s")?;
    let li = espec.output_pos("loss")?;
    let gi = if want {
        Some(espec.output_pos("g_smashed")?)
    } else {
        None
    };
    let mut cut_buf = match gi {
        Some(gi) => take_f32_buf(outs, gi),
        None => Vec::new(),
    };
    let loss = {
        let cut = if want { Some(&mut cut_buf) } else { None };
        step(cut, out_f32_vec(outs, ti))
    };
    if let Some(gi) = gi {
        outs[gi] = TensorValue::F32(cut_buf);
    }
    set_scalar_f32(outs, li, loss);
    Ok(())
}

// ---------------------------------------------------------------------------
// per-task dispatch
// ---------------------------------------------------------------------------

fn exec_vision(
    m: &VisionModel,
    espec: &EntrySpec,
    inputs: &[TensorRef<'_>],
    outs: &mut Vec<TensorValue>,
) -> Result<()> {
    match espec.name.as_str() {
        "local_loss" => {
            let loss = m.local_loss(
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "x")?,
                i32_arg(espec, inputs, "y")?,
            );
            set_scalar_f32(outs, espec.output_pos("loss")?, loss);
        }
        "zo_step" => {
            let ti = espec.output_pos("theta_l")?;
            let li = espec.output_pos("loss")?;
            let loss = m.zo_step_into(
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "x")?,
                i32_arg(espec, inputs, "y")?,
                scalar_i32(espec, inputs, "seed")?,
                scalar_f32(espec, inputs, "mu")?,
                scalar_f32(espec, inputs, "lr")?,
                scalar_i32(espec, inputs, "n_pert")?,
                out_f32_vec(outs, ti),
            );
            set_scalar_f32(outs, li, loss);
        }
        "fo_step" => {
            let ti = espec.output_pos("theta_l")?;
            let li = espec.output_pos("loss")?;
            let loss = m.fo_step_into(
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "x")?,
                i32_arg(espec, inputs, "y")?,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
            set_scalar_f32(outs, li, loss);
        }
        "client_fwd" => {
            let si = espec.output_pos("smashed")?;
            m.client_fwd_into(
                f32_arg(espec, inputs, "theta_c")?,
                f32_arg(espec, inputs, "x")?,
                out_f32_vec(outs, si),
            );
        }
        "server_step" | "server_step_cutgrad" => {
            let theta_s = f32_arg(espec, inputs, "theta_s")?;
            let smashed = f32_arg(espec, inputs, "smashed")?;
            let y = i32_arg(espec, inputs, "y")?;
            let lr = scalar_f32(espec, inputs, "lr")?;
            run_server_step(espec, outs, |cut, th| {
                m.server_step_into(theta_s, smashed, y, lr, cut, th)
            })?;
        }
        "client_bp_step" => {
            let ti = espec.output_pos("theta_c")?;
            m.client_bp_step_into(
                f32_arg(espec, inputs, "theta_c")?,
                f32_arg(espec, inputs, "x")?,
                f32_arg(espec, inputs, "g_smashed")?,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
        }
        "aux_align" => {
            let ti = espec.output_pos("theta_l")?;
            m.aux_align_into(
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "smashed")?,
                i32_arg(espec, inputs, "y")?,
                f32_arg(espec, inputs, "g_smashed")?,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
        }
        "eval_full" => {
            let (s1, s2) = m.eval(
                f32_arg(espec, inputs, "theta_c")?,
                f32_arg(espec, inputs, "theta_s")?,
                f32_arg(espec, inputs, "x")?,
                i32_arg(espec, inputs, "y")?,
            );
            set_scalar_f32(outs, espec.output_pos("stat1")?, s1);
            set_scalar_f32(outs, espec.output_pos("stat2")?, s2);
        }
        "hvp" => {
            let hi = espec.output_pos("hv")?;
            let hv = m.hvp(
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "x")?,
                i32_arg(espec, inputs, "y")?,
                f32_arg(espec, inputs, "v")?,
            );
            outs[hi] = TensorValue::F32(hv);
        }
        other => bail!("vision model has no entry {other}"),
    }
    Ok(())
}

fn exec_lm(
    m: &LmModel,
    vspec: &VariantSpec,
    espec: &EntrySpec,
    inputs: &[TensorRef<'_>],
    outs: &mut Vec<TensorValue>,
) -> Result<()> {
    let seq: usize = vspec.x_shape.iter().product::<usize>().max(1);
    let base = f32_arg(espec, inputs, "base")?;
    match espec.name.as_str() {
        "local_loss" => {
            let loss = m.local_loss(
                base,
                f32_arg(espec, inputs, "theta_l")?,
                i32_arg(espec, inputs, "x")?,
                seq,
            );
            set_scalar_f32(outs, espec.output_pos("loss")?, loss);
        }
        "zo_step" => {
            let ti = espec.output_pos("theta_l")?;
            let li = espec.output_pos("loss")?;
            let loss = m.zo_step_into(
                base,
                f32_arg(espec, inputs, "theta_l")?,
                i32_arg(espec, inputs, "x")?,
                seq,
                scalar_i32(espec, inputs, "seed")?,
                scalar_f32(espec, inputs, "mu")?,
                scalar_f32(espec, inputs, "lr")?,
                scalar_i32(espec, inputs, "n_pert")?,
                out_f32_vec(outs, ti),
            );
            set_scalar_f32(outs, li, loss);
        }
        "fo_step" => {
            let ti = espec.output_pos("theta_l")?;
            let li = espec.output_pos("loss")?;
            let loss = m.fo_step_into(
                base,
                f32_arg(espec, inputs, "theta_l")?,
                i32_arg(espec, inputs, "x")?,
                seq,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
            set_scalar_f32(outs, li, loss);
        }
        "client_fwd" => {
            let si = espec.output_pos("smashed")?;
            m.client_fwd_into(
                base,
                f32_arg(espec, inputs, "theta_c")?,
                i32_arg(espec, inputs, "x")?,
                out_f32_vec(outs, si),
            );
        }
        "server_step" | "server_step_cutgrad" => {
            let theta_s = f32_arg(espec, inputs, "theta_s")?;
            let smashed = f32_arg(espec, inputs, "smashed")?;
            let y = i32_arg(espec, inputs, "y")?;
            let lr = scalar_f32(espec, inputs, "lr")?;
            run_server_step(espec, outs, |cut, th| {
                m.server_step_into(theta_s, smashed, y, seq, lr, cut, th)
            })?;
        }
        "client_bp_step" => {
            let ti = espec.output_pos("theta_c")?;
            m.client_bp_step_into(
                base,
                f32_arg(espec, inputs, "theta_c")?,
                i32_arg(espec, inputs, "x")?,
                f32_arg(espec, inputs, "g_smashed")?,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
        }
        "aux_align" => {
            // round driver sends the token batch as `y` for LM tasks
            let ti = espec.output_pos("theta_l")?;
            m.aux_align_into(
                base,
                f32_arg(espec, inputs, "theta_l")?,
                f32_arg(espec, inputs, "smashed")?,
                i32_arg(espec, inputs, "y")?,
                seq,
                f32_arg(espec, inputs, "g_smashed")?,
                scalar_f32(espec, inputs, "lr")?,
                out_f32_vec(outs, ti),
            );
        }
        "eval_full" => {
            let (s1, s2) = m.eval(
                base,
                f32_arg(espec, inputs, "theta_c")?,
                f32_arg(espec, inputs, "theta_s")?,
                i32_arg(espec, inputs, "x")?,
                seq,
            );
            set_scalar_f32(outs, espec.output_pos("stat1")?, s1);
            set_scalar_f32(outs, espec.output_pos("stat2")?, s2);
        }
        other => bail!("lm model has no entry {other}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn spec(name: &str) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: vec![2],
            dtype: crate::runtime::manifest::DType::F32,
        }
    }

    fn espec_with(outputs: &[&str]) -> EntrySpec {
        EntrySpec {
            name: "t".into(),
            file: std::path::PathBuf::new(),
            inputs: vec![spec("a"), spec("b")],
            outputs: outputs.iter().map(|n| spec(n)).collect(),
        }
    }

    #[test]
    fn positional_args_resolve_by_name() {
        let e = espec_with(&["o"]);
        let va = [1.0f32, 2.0];
        let vb = [3.0f32, 4.0];
        let inputs = [TensorRef::F32(&va), TensorRef::F32(&vb)];
        assert_eq!(f32_arg(&e, &inputs, "a").unwrap(), &va);
        assert_eq!(f32_arg(&e, &inputs, "b").unwrap(), &vb);
        assert!(f32_arg(&e, &inputs, "c").is_err());
    }

    #[test]
    fn out_slots_reuse_and_normalize() {
        let e = espec_with(&["o1", "o2"]);
        let mut outs = vec![TensorValue::F32(vec![9.0; 4])];
        prepare_outs(&e, &mut outs);
        assert_eq!(outs.len(), 2);
        {
            let v = out_f32_vec(&mut outs, 0);
            assert_eq!(v.len(), 4, "existing buffer kept for reuse");
            v.clear();
            v.extend_from_slice(&[1.0, 2.0]);
        }
        set_scalar_f32(&mut outs, 1, 7.0);
        assert_eq!(outs[0].as_f32().unwrap(), &[1.0, 2.0]);
        assert_eq!(outs[1].scalar_f32().unwrap(), 7.0);
        // scalar slot converts to a vec slot on demand
        let v = out_f32_vec(&mut outs, 1);
        assert!(v.is_empty());
    }
}
