//! Artifact manifest: the typed view of `artifacts/manifest.json`.
//!
//! The manifest is the L2→L3 contract: per variant it lists the HLO entry
//! files with their input/output tensor specs, flat-parameter sizes and
//! layouts, the analytic cost model, binary blob files (frozen base, init
//! params), and golden output digests for the cross-language test.

use crate::util::json::{self, Value};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(TensorSpec {
            name: v
                .get("name")
                .and_then(Value::as_str)
                .context("tensor name")?
                .to_string(),
            shape: v
                .get("shape")
                .and_then(Value::usize_vec)
                .context("tensor shape")?,
            dtype: DType::parse(
                v.get("dtype").and_then(Value::as_str).context("dtype")?,
            )?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntrySpec {
    /// Position of a named output in this entry's output list — the one
    /// resolution rule shared by the engine's out-slots and the round
    /// driver's scratch arenas.
    pub fn output_pos(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("{}: no output {name}", self.name))
    }
}

/// Analytic per-sample cost model emitted by L2 (see models/base.py).
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    pub params_client: usize,
    pub params_aux: usize,
    pub params_server: usize,
    pub act_cache_client: usize,
    pub act_cache_aux: usize,
    pub act_cache_server: usize,
    pub act_peak_client: usize,
    pub act_peak_aux: usize,
    pub act_peak_server: usize,
    pub flops_fwd_client: usize,
    pub flops_fwd_aux: usize,
    pub flops_fwd_server: usize,
    pub smashed_elems: usize,
    pub target_elems: usize,
}

#[derive(Debug, Clone)]
pub struct GoldenOutput {
    pub shape: Vec<usize>,
    pub head: Vec<f64>,
    pub sum: f64,
    pub l2: f64,
}

#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub family: String,
    pub task: String,
    pub optimizer: String,
    pub opt_state: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    pub smashed_shape: Vec<usize>,
    pub size_client: usize,
    pub size_aux: usize,
    pub size_server: usize,
    pub size_base: usize,
    pub cost: CostModel,
    pub entries: BTreeMap<String, EntrySpec>,
    pub files: BTreeMap<String, PathBuf>,
    pub golden: BTreeMap<String, Vec<GoldenOutput>>,
    pub dir: PathBuf,
}

impl VariantSpec {
    pub fn size_local(&self) -> usize {
        self.size_client + self.size_aux
    }

    pub fn smashed_elems_per_batch(&self) -> usize {
        self.batch * self.smashed_shape.iter().product::<usize>()
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("variant {} has no entry {name}", self.name))
    }

    pub fn blob(&self, key: &str) -> Result<Vec<f32>> {
        let rel = self
            .files
            .get(key)
            .ok_or_else(|| anyhow!("variant {} has no blob {key}", self.name))?;
        let path = self.dir.join(rel);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("blob {} has non-f32 length {}", path.display(), bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub variants: BTreeMap<String, VariantSpec>,
    pub synth: Value,
    pub root: PathBuf,
}

impl Manifest {
    /// Locate `artifacts/` relative to the repo root (works from tests,
    /// benches, and examples regardless of cwd).
    pub fn default_path() -> PathBuf {
        let mut dir = std::env::current_dir().unwrap_or_default();
        loop {
            let cand = dir.join("artifacts/manifest.json");
            if cand.exists() {
                return dir.join("artifacts");
            }
            if !dir.pop() {
                return PathBuf::from("artifacts");
            }
        }
    }

    /// Load the default artifact set, generating it first when missing or
    /// stale (see [`crate::runtime::artifacts`]).
    pub fn load_default() -> Result<Self> {
        let dir = crate::runtime::artifacts::ensure_default()?;
        Self::load(&dir)
    }

    pub fn load(root: &Path) -> Result<Self> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = json::parse(&text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        for (name, vv) in v
            .get("variants")
            .and_then(Value::as_obj)
            .context("manifest.variants")?
        {
            variants.insert(
                name.clone(),
                parse_variant(name, vv, &root.join(name))
                    .with_context(|| format!("variant {name}"))?,
            );
        }
        Ok(Manifest {
            variants,
            synth: v.get("synth").cloned().unwrap_or(Value::Null),
            root: root.to_path_buf(),
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("no variant {name} in manifest"))
    }
}

fn parse_cost(v: &Value) -> Result<CostModel> {
    let g = |k: &str| -> usize {
        v.get(k).and_then(Value::as_usize).unwrap_or(0)
    };
    Ok(CostModel {
        params_client: g("params_client"),
        params_aux: g("params_aux"),
        params_server: g("params_server"),
        act_cache_client: g("act_cache_client"),
        act_cache_aux: g("act_cache_aux"),
        act_cache_server: g("act_cache_server"),
        act_peak_client: g("act_peak_client"),
        act_peak_aux: g("act_peak_aux"),
        act_peak_server: g("act_peak_server"),
        flops_fwd_client: g("flops_fwd_client"),
        flops_fwd_aux: g("flops_fwd_aux"),
        flops_fwd_server: g("flops_fwd_server"),
        smashed_elems: g("smashed_elems"),
        target_elems: g("target_elems").max(1),
    })
}

fn parse_variant(name: &str, v: &Value, dir: &Path) -> Result<VariantSpec> {
    let s = |k: &str| -> Result<&str> {
        v.get(k)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow!("missing {k}"))
    };
    let u = |k: &str| -> Result<usize> {
        v.get(k)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("missing {k}"))
    };
    let sizes = v.get("sizes").context("sizes")?;
    let size = |k: &str| -> Result<usize> {
        sizes
            .get(k)
            .and_then(Value::as_usize)
            .ok_or_else(|| anyhow!("missing sizes.{k}"))
    };

    let mut entries = BTreeMap::new();
    for (en, ev) in v
        .get("entries")
        .and_then(Value::as_obj)
        .context("entries")?
    {
        let parse_list = |k: &str| -> Result<Vec<TensorSpec>> {
            ev.get(k)
                .and_then(Value::as_arr)
                .context("tensor list")?
                .iter()
                .map(TensorSpec::parse)
                .collect()
        };
        entries.insert(
            en.clone(),
            EntrySpec {
                name: en.clone(),
                file: dir.join(
                    ev.get("file").and_then(Value::as_str).context("file")?,
                ),
                inputs: parse_list("inputs")?,
                outputs: parse_list("outputs")?,
            },
        );
    }

    let mut files = BTreeMap::new();
    if let Some(fm) = v.get("files").and_then(Value::as_obj) {
        for (k, fv) in fm {
            files.insert(
                k.clone(),
                PathBuf::from(fv.as_str().unwrap_or_default()),
            );
        }
    }

    let mut golden = BTreeMap::new();
    if let Some(gm) = v.get("golden").and_then(Value::as_obj) {
        for (k, gv) in gm {
            let outs = gv
                .get("outputs")
                .and_then(Value::as_arr)
                .unwrap_or(&[])
                .iter()
                .map(|o| {
                    Ok(GoldenOutput {
                        shape: o
                            .get("shape")
                            .and_then(Value::usize_vec)
                            .context("golden shape")?,
                        head: o
                            .get("head")
                            .and_then(Value::f64_vec)
                            .context("golden head")?,
                        sum: o
                            .get("sum")
                            .and_then(Value::as_f64)
                            .context("golden sum")?,
                        l2: o
                            .get("l2")
                            .and_then(Value::as_f64)
                            .context("golden l2")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            golden.insert(k.clone(), outs);
        }
    }

    Ok(VariantSpec {
        name: name.to_string(),
        family: s("family")?.to_string(),
        task: s("task")?.to_string(),
        optimizer: s("optimizer")?.to_string(),
        opt_state: u("opt_state")?,
        batch: u("batch")?,
        eval_batch: u("eval_batch")?,
        x_shape: v.get("x_shape").and_then(Value::usize_vec).context("x_shape")?,
        y_shape: v.get("y_shape").and_then(Value::usize_vec).context("y_shape")?,
        smashed_shape: v
            .get("smashed_shape")
            .and_then(Value::usize_vec)
            .context("smashed_shape")?,
        size_client: size("client")?,
        size_aux: size("aux")?,
        size_server: size("server")?,
        size_base: size("base")?,
        cost: parse_cost(v.get("cost").context("cost")?)?,
        entries,
        files,
        golden,
        dir: dir.to_path_buf(),
    })
}
