//! Host-side tensor values crossing the Rust <-> XLA boundary.
//!
//! Everything the protocol moves is either a flat `f32` vector (parameters,
//! activations, gradients) or an `i32` batch (tokens/labels) or a scalar.
//! `TensorValue` is that closed union; `runtime::Session` marshals it to/from
//! `xla::Literal` using the entry's `TensorSpec` shapes.
//!
//! [`TensorRef`] is the borrowed mirror of `TensorValue` for the
//! zero-allocation invoke path: callers that already own the backing
//! buffers (the round driver's per-client θ, the loader's reused batch
//! buffers, the frozen base blob) pass views instead of cloning a
//! `Vec` per argument per step. `Session::invoke_into` takes `TensorRef`s
//! and writes outputs into caller-owned `TensorValue` slots.

use super::manifest::{DType, TensorSpec};
use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorValue {
    F32(Vec<f32>),
    I32(Vec<i32>),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl TensorValue {
    pub fn dtype(&self) -> DType {
        match self {
            TensorValue::F32(_) | TensorValue::ScalarF32(_) => DType::F32,
            TensorValue::I32(_) | TensorValue::ScalarI32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32(v) => v.len(),
            TensorValue::I32(v) => v.len(),
            _ => 1,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorValue::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            TensorValue::F32(v) => Ok(v),
            TensorValue::ScalarF32(s) => Ok(vec![s]),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            TensorValue::ScalarF32(s) => Ok(*s),
            TensorValue::F32(v) if v.len() == 1 => Ok(v[0]),
            other => bail!("expected f32 scalar, got len {}", other.len()),
        }
    }

    /// Validate value against a spec (shape product + dtype).
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input {}: dtype mismatch (got {:?}, want {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        let want = spec.elems();
        let scalar = matches!(
            self,
            TensorValue::ScalarF32(_) | TensorValue::ScalarI32(_)
        );
        if scalar {
            if !spec.shape.is_empty() {
                bail!("input {}: scalar given for shaped tensor", spec.name);
            }
        } else if self.len() != want {
            bail!(
                "input {}: length mismatch (got {}, want {} = {:?})",
                spec.name,
                self.len(),
                want,
                spec.shape
            );
        }
        Ok(())
    }
}

/// Borrowed view of a [`TensorValue`] (scalars are `Copy`, so they are
/// carried by value). The lifetime is the owning buffer's, which lets the
/// round driver thread loader/θ/base buffers through `invoke_into` without
/// per-step clones.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TensorRef<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> TensorRef<'a> {
    pub fn dtype(self) -> DType {
        match self {
            TensorRef::F32(_) | TensorRef::ScalarF32(_) => DType::F32,
            TensorRef::I32(_) | TensorRef::ScalarI32(_) => DType::I32,
        }
    }

    pub fn len(self) -> usize {
        match self {
            TensorRef::F32(v) => v.len(),
            TensorRef::I32(v) => v.len(),
            _ => 1,
        }
    }

    pub fn is_empty(self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(self) -> Result<&'a [f32]> {
        match self {
            TensorRef::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn as_i32(self) -> Result<&'a [i32]> {
        match self {
            TensorRef::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", other.dtype()),
        }
    }

    pub fn scalar_f32(self) -> Result<f32> {
        match self {
            TensorRef::ScalarF32(s) => Ok(s),
            TensorRef::F32(v) if v.len() == 1 => Ok(v[0]),
            other => bail!("expected f32 scalar, got len {}", other.len()),
        }
    }

    pub fn scalar_i32(self) -> Result<i32> {
        match self {
            TensorRef::ScalarI32(s) => Ok(s),
            TensorRef::I32(v) if v.len() == 1 => Ok(v[0]),
            other => bail!("expected i32 scalar, got len {}", other.len()),
        }
    }

    /// Validate value against a spec (shape product + dtype) — mirrors
    /// [`TensorValue::check`] exactly.
    pub fn check(self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!(
                "input {}: dtype mismatch (got {:?}, want {:?})",
                spec.name,
                self.dtype(),
                spec.dtype
            );
        }
        let want = spec.elems();
        let scalar = matches!(
            self,
            TensorRef::ScalarF32(_) | TensorRef::ScalarI32(_)
        );
        if scalar {
            if !spec.shape.is_empty() {
                bail!("input {}: scalar given for shaped tensor", spec.name);
            }
        } else if self.len() != want {
            bail!(
                "input {}: length mismatch (got {}, want {} = {:?})",
                spec.name,
                self.len(),
                want,
                spec.shape
            );
        }
        Ok(())
    }

    /// Materialize an owned copy (cold paths only).
    pub fn to_value(self) -> TensorValue {
        match self {
            TensorRef::F32(v) => TensorValue::F32(v.to_vec()),
            TensorRef::I32(v) => TensorValue::I32(v.to_vec()),
            TensorRef::ScalarF32(s) => TensorValue::ScalarF32(s),
            TensorRef::ScalarI32(s) => TensorValue::ScalarI32(s),
        }
    }
}

impl TensorValue {
    /// Borrow this value as a [`TensorRef`].
    pub fn view(&self) -> TensorRef<'_> {
        match self {
            TensorValue::F32(v) => TensorRef::F32(v),
            TensorValue::I32(v) => TensorRef::I32(v),
            TensorValue::ScalarF32(s) => TensorRef::ScalarF32(*s),
            TensorValue::ScalarI32(s) => TensorRef::ScalarI32(*s),
        }
    }
}

impl<'a> From<&'a [f32]> for TensorRef<'a> {
    fn from(v: &'a [f32]) -> Self {
        TensorRef::F32(v)
    }
}

impl<'a> From<&'a [i32]> for TensorRef<'a> {
    fn from(v: &'a [i32]) -> Self {
        TensorRef::I32(v)
    }
}

impl From<f32> for TensorRef<'_> {
    fn from(v: f32) -> Self {
        TensorRef::ScalarF32(v)
    }
}

impl From<i32> for TensorRef<'_> {
    fn from(v: i32) -> Self {
        TensorRef::ScalarI32(v)
    }
}

impl From<Vec<f32>> for TensorValue {
    fn from(v: Vec<f32>) -> Self {
        TensorValue::F32(v)
    }
}

impl From<Vec<i32>> for TensorValue {
    fn from(v: Vec<i32>) -> Self {
        TensorValue::I32(v)
    }
}

impl From<f32> for TensorValue {
    fn from(v: f32) -> Self {
        TensorValue::ScalarF32(v)
    }
}

impl From<i32> for TensorValue {
    fn from(v: i32) -> Self {
        TensorValue::ScalarI32(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
            dtype,
        }
    }

    #[test]
    fn check_accepts_matching() {
        let v = TensorValue::F32(vec![0.0; 6]);
        assert!(v.check(&spec("x", &[2, 3], DType::F32)).is_ok());
        let s = TensorValue::ScalarI32(3);
        assert!(s.check(&spec("n", &[], DType::I32)).is_ok());
    }

    #[test]
    fn check_rejects_mismatch() {
        let v = TensorValue::F32(vec![0.0; 5]);
        assert!(v.check(&spec("x", &[2, 3], DType::F32)).is_err());
        assert!(v.check(&spec("x", &[5], DType::I32)).is_err());
        let s = TensorValue::ScalarF32(1.0);
        assert!(s.check(&spec("x", &[1], DType::F32)).is_err());
    }

    #[test]
    fn conversions() {
        let v: TensorValue = vec![1.0f32, 2.0].into();
        assert_eq!(v.as_f32().unwrap(), &[1.0, 2.0]);
        let s: TensorValue = 3.5f32.into();
        assert_eq!(s.scalar_f32().unwrap(), 3.5);
        assert!(s.as_f32().is_err());
    }

    #[test]
    fn refs_mirror_values() {
        let v = TensorValue::F32(vec![1.0, 2.0, 3.0]);
        let r = v.view();
        assert_eq!(r.len(), 3);
        assert_eq!(r.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        assert!(r.check(&spec("x", &[3], DType::F32)).is_ok());
        assert!(r.check(&spec("x", &[4], DType::F32)).is_err());
        assert_eq!(r.to_value(), v);

        let s = TensorValue::ScalarI32(7).view();
        assert_eq!(s.scalar_i32().unwrap(), 7);
        assert!(s.check(&spec("n", &[], DType::I32)).is_ok());
        assert!(s.as_i32().is_err());

        let buf = [4i32, 5];
        let t: TensorRef = (&buf[..]).into();
        assert_eq!(t.as_i32().unwrap(), &[4, 5]);
        assert!(t.scalar_i32().is_err());
    }
}
