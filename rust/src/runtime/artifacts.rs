//! Deterministic artifact generation (substrate S21).
//!
//! The L2→L3 contract is an on-disk `artifacts/` directory: a manifest with
//! per-variant entry specs, init/base parameter blobs, and golden output
//! digests. When the AOT (JAX/Pallas) toolchain is unavailable — the
//! offline default — this module synthesizes the full artifact set for the
//! native reference engine: the same manifest schema, blobs written as
//! little-endian f32, entry marker files, and goldens recorded by actually
//! executing every entry once through [`crate::runtime::native::Engine`].
//!
//! Generation is deterministic (all streams are counter-based) and atomic:
//! the tree is built under `artifacts.tmp.<pid>` and renamed into place, so
//! concurrent readers never observe a half-written manifest.

use crate::golden;
use crate::runtime::manifest::{
    CostModel, DType, EntrySpec, GoldenOutput, Manifest, TensorSpec,
    VariantSpec,
};
use crate::runtime::native::lm::{AuxKind, VOCAB};
use crate::runtime::native::Engine;
use crate::util::json::Value;
use crate::util::rng::mix64;
use crate::zo::stream::{fold_seed, PerturbStream};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Bumped whenever the native model definition changes; a manifest carrying
/// a different tag is regenerated on load.
pub const ENGINE_TAG: &str = "native-ref-v1";

const SEQ: usize = 96;
const PIXELS: usize = 768;
const CLASSES: usize = 10;

static GEN_LOCK: Mutex<()> = Mutex::new(());

/// Locate the default artifact set, generating it if missing or stale.
/// Returns the `artifacts/` directory.
pub fn ensure_default() -> Result<PathBuf> {
    let _guard = GEN_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(dir) = find_existing() {
        return match manifest_tag(&dir) {
            Some(tag) if tag == ENGINE_TAG => Ok(dir),
            Some(_) => {
                // our own output from an older engine: regenerate in place
                log::info!(
                    "regenerating stale native artifact set at {}",
                    dir.display()
                );
                let parent =
                    dir.parent().unwrap_or(Path::new(".")).to_path_buf();
                generate_at(&parent, true)
            }
            // no generated_by tag: a foreign artifact set (e.g. AOT
            // toolchain output) — never delete what we didn't generate
            None => Ok(dir),
        };
    }
    let root = find_repo_root();
    log::info!(
        "no artifacts found — generating native set under {}",
        root.display()
    );
    generate_at(&root, false)
}

/// Walk up from cwd looking for `artifacts/manifest.json`, but never past
/// the repo root (the first ancestor holding a Cargo.toml) — an unrelated
/// `artifacts/` directory above the repo must not be picked up.
fn find_existing() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if dir.join("Cargo.toml").exists() || !dir.pop() {
            return None;
        }
    }
}

fn find_repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir = cwd.clone();
    loop {
        if dir.join("Cargo.toml").exists() {
            return dir;
        }
        if !dir.pop() {
            return cwd;
        }
    }
}

/// The `generated_by` tag of an artifact manifest, if it has one. `None`
/// means the tree was not produced by this generator (or is unreadable).
fn manifest_tag(dir: &Path) -> Option<String> {
    let text = std::fs::read_to_string(dir.join("manifest.json")).ok()?;
    let v = crate::util::json::parse(&text).ok()?;
    v.get("generated_by")
        .and_then(Value::as_str)
        .map(str::to_string)
}

fn generate_at(root: &Path, replace: bool) -> Result<PathBuf> {
    let tmp = root.join(format!("artifacts.tmp.{}", std::process::id()));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp).ok();
    }
    std::fs::create_dir_all(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    let result = generate_into(&tmp);
    let dest = root.join("artifacts");
    match result {
        Ok(()) => {
            if replace && dest.exists() {
                std::fs::remove_dir_all(&dest)
                    .with_context(|| format!("clearing {}", dest.display()))?;
            }
            match std::fs::rename(&tmp, &dest) {
                Ok(()) => Ok(dest),
                Err(_) if dest.join("manifest.json").exists() => {
                    // another process won the race; use theirs
                    std::fs::remove_dir_all(&tmp).ok();
                    Ok(dest)
                }
                Err(e) => Err(e).with_context(|| {
                    format!("installing artifacts at {}", dest.display())
                }),
            }
        }
        Err(e) => {
            std::fs::remove_dir_all(&tmp).ok();
            Err(e)
        }
    }
}

// ---------------------------------------------------------------------------
// variant definitions
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum Arch {
    /// Gabor-energy vision client with `q` features
    Vision { q: usize },
    /// LoRA-bigram LM client with embedding width `e`
    Lm { e: usize, aux: AuxKind },
}

#[derive(Clone, Copy)]
struct VDef {
    name: &'static str,
    arch: Arch,
    batch: usize,
    eval_batch: usize,
    /// include the locked-exchange + alignment entries
    full: bool,
    /// include the Hessian-vector-product entry (Fig 7)
    hvp: bool,
}

fn defs() -> Vec<VDef> {
    use AuxKind::*;
    let v = |name, q, full, hvp| VDef {
        name,
        arch: Arch::Vision { q },
        batch: 32,
        eval_batch: 64,
        full,
        hvp,
    };
    let l = |name, e, aux| VDef {
        name,
        arch: Arch::Lm { e, aux },
        batch: 4,
        eval_batch: 8,
        full: true,
        hvp: false,
    };
    vec![
        v("cnn_c1", 36, true, true),
        v("cnn_c2", 18, false, false),
        v("cnn_c3", 27, false, false),
        l("gpt2nano_c1_a1", 16, Linear),
        // kernel-path twin: same model lowered through the Pallas kernels
        l("gpt2nano_c1_a1_pallas", 16, Linear),
        l("gpt2micro_c2_a0", 24, Bias),
        l("gpt2micro_c2_a1", 24, Linear),
        l("gpt2micro_c2_a1_pallas", 24, Linear),
        l("gpt2micro_c2_a2", 24, Mlp(8)),
        l("gpt2micro_c2_a3", 24, Mlp(16)),
        l("gpt2micro_c3_a1", 32, Linear),
    ]
}

impl VDef {
    fn task(&self) -> &'static str {
        match self.arch {
            Arch::Vision { .. } => "vision",
            Arch::Lm { .. } => "lm",
        }
    }

    fn family(&self) -> &'static str {
        match self.arch {
            Arch::Vision { .. } => "cnn",
            Arch::Lm { .. } => "gpt2",
        }
    }

    fn sizes(&self) -> (usize, usize, usize, usize) {
        // (client, aux, server, base)
        match self.arch {
            Arch::Vision { q } => {
                (2 * q, q * CLASSES + CLASSES, q * CLASSES + CLASSES, 0)
            }
            Arch::Lm { e, aux } => {
                (VOCAB * e, aux.size(e), e * VOCAB + VOCAB, VOCAB * e)
            }
        }
    }

    fn entry_names(&self) -> Vec<&'static str> {
        let mut es = vec![
            "local_loss",
            "zo_step",
            "fo_step",
            "client_fwd",
            "server_step",
            "eval_full",
        ];
        if self.full {
            es.extend([
                "server_step_cutgrad",
                "client_bp_step",
                "aux_align",
            ]);
        }
        if self.hvp {
            es.push("hvp");
        }
        es
    }

    fn cost(&self) -> CostModel {
        let (pc, pa, ps, _) = self.sizes();
        match self.arch {
            Arch::Vision { q } => CostModel {
                params_client: pc,
                params_aux: pa,
                params_server: ps,
                act_cache_client: 8 * q,
                act_cache_aux: 4 * CLASSES,
                act_cache_server: 4 * CLASSES,
                act_peak_client: 4 * q,
                act_peak_aux: 4 * CLASSES,
                act_peak_server: 4 * CLASSES,
                flops_fwd_client: 4 * PIXELS * q + 4 * q,
                flops_fwd_aux: 2 * q * CLASSES + CLASSES,
                flops_fwd_server: 2 * q * CLASSES + CLASSES,
                smashed_elems: q,
                target_elems: 1,
            },
            Arch::Lm { e, .. } => CostModel {
                params_client: pc,
                params_aux: pa,
                params_server: ps,
                act_cache_client: 4 * SEQ * e,
                act_cache_aux: 4 * SEQ * VOCAB,
                act_cache_server: 4 * SEQ * VOCAB,
                act_peak_client: 4 * e,
                act_peak_aux: 4 * VOCAB,
                act_peak_server: 4 * VOCAB,
                flops_fwd_client: SEQ * 4 * e,
                flops_fwd_aux: SEQ * (2 * e * VOCAB + VOCAB),
                flops_fwd_server: SEQ * (2 * e * VOCAB + VOCAB),
                smashed_elems: SEQ * e,
                target_elems: SEQ,
            },
        }
    }

    fn x_shape(&self) -> Vec<usize> {
        match self.arch {
            Arch::Vision { .. } => vec![16, 16, 3],
            Arch::Lm { .. } => vec![SEQ],
        }
    }

    fn y_shape(&self) -> Vec<usize> {
        match self.arch {
            Arch::Vision { .. } => vec![],
            Arch::Lm { .. } => vec![SEQ],
        }
    }

    fn smashed_shape(&self) -> Vec<usize> {
        match self.arch {
            Arch::Vision { q } => vec![q],
            Arch::Lm { e, .. } => vec![SEQ, e],
        }
    }

    fn init_theta_l(&self) -> Vec<f32> {
        let (nc, na, _, _) = self.sizes();
        match self.arch {
            Arch::Vision { q } => {
                let mut t = vec![0.0f32; nc + na];
                for s in t.iter_mut().take(q) {
                    *s = 2.0; // feature gains start at 2, biases/aux at 0
                }
                t
            }
            Arch::Lm { .. } => vec![0.0f32; nc + na],
        }
    }

    fn init_theta_s(&self) -> Vec<f32> {
        vec![0.0f32; self.sizes().2]
    }

    fn frozen_base(&self) -> Option<Vec<f32>> {
        match self.arch {
            Arch::Vision { .. } => None,
            Arch::Lm { e, .. } => Some(
                PerturbStream::new(fold_seed(0xBA5E, e as u32))
                    .take_vec(VOCAB * e)
                    .into_iter()
                    .map(|v| v * 0.3)
                    .collect(),
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// entry spec construction
// ---------------------------------------------------------------------------

fn t(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape: shape.to_vec(),
        dtype,
    }
}

fn entry_spec(def: &VDef, entry: &str, dir: &Path) -> EntrySpec {
    let (nc, na, ns, nb) = def.sizes();
    let nl = nc + na;
    let b = def.batch;
    let eb = def.eval_batch;
    let is_lm = matches!(def.arch, Arch::Lm { .. });
    let xdt = if is_lm { DType::I32 } else { DType::F32 };
    let xsh: Vec<usize> = if is_lm { vec![SEQ] } else { vec![PIXELS] };
    let ysh: Vec<usize> = if is_lm { vec![SEQ] } else { vec![] };
    let smsh = def.smashed_shape();
    let batched = |n: usize, per: &[usize]| -> Vec<usize> {
        let mut s = vec![n];
        s.extend_from_slice(per);
        s
    };

    let mut inputs: Vec<TensorSpec> = Vec::new();
    if nb > 0 {
        inputs.push(t("base", &[nb], DType::F32));
    }
    let x = |n: usize| t("x", &batched(n, &xsh), xdt);
    let y = |n: usize| t("y", &batched(n, &ysh), DType::I32);
    let smashed = |name: &str| t(name, &batched(b, &smsh), DType::F32);
    let outputs: Vec<TensorSpec>;
    match entry {
        "local_loss" => {
            inputs.extend([t("theta_l", &[nl], DType::F32), x(b), y(b)]);
            outputs = vec![t("loss", &[], DType::F32)];
        }
        "zo_step" => {
            inputs.extend([
                t("theta_l", &[nl], DType::F32),
                x(b),
                y(b),
                t("seed", &[], DType::I32),
                t("mu", &[], DType::F32),
                t("lr", &[], DType::F32),
                t("n_pert", &[], DType::I32),
            ]);
            outputs = vec![
                t("theta_l", &[nl], DType::F32),
                t("loss", &[], DType::F32),
            ];
        }
        "fo_step" => {
            inputs.extend([
                t("theta_l", &[nl], DType::F32),
                x(b),
                y(b),
                t("lr", &[], DType::F32),
            ]);
            outputs = vec![
                t("theta_l", &[nl], DType::F32),
                t("loss", &[], DType::F32),
            ];
        }
        "client_fwd" => {
            inputs.extend([t("theta_c", &[nc], DType::F32), x(b)]);
            outputs = vec![smashed("smashed")];
        }
        "server_step" | "server_step_cutgrad" => {
            inputs.extend([
                t("theta_s", &[ns], DType::F32),
                smashed("smashed"),
                y(b),
                t("lr", &[], DType::F32),
            ]);
            let mut outs = vec![
                t("theta_s", &[ns], DType::F32),
                t("loss", &[], DType::F32),
            ];
            if entry == "server_step_cutgrad" {
                outs.push(smashed("g_smashed"));
            }
            outputs = outs;
        }
        "client_bp_step" => {
            inputs.extend([
                t("theta_c", &[nc], DType::F32),
                x(b),
                smashed("g_smashed"),
                t("lr", &[], DType::F32),
            ]);
            outputs = vec![t("theta_c", &[nc], DType::F32)];
        }
        "aux_align" => {
            inputs.extend([
                t("theta_l", &[nl], DType::F32),
                smashed("smashed"),
                y(b),
                smashed("g_smashed"),
                t("lr", &[], DType::F32),
            ]);
            outputs = vec![t("theta_l", &[nl], DType::F32)];
        }
        "eval_full" => {
            inputs.extend([
                t("theta_c", &[nc], DType::F32),
                t("theta_s", &[ns], DType::F32),
                x(eb),
                y(eb),
            ]);
            outputs = vec![
                t("stat1", &[], DType::F32),
                t("stat2", &[], DType::F32),
            ];
        }
        "hvp" => {
            inputs.extend([
                t("theta_l", &[nl], DType::F32),
                x(b),
                y(b),
                t("v", &[nl], DType::F32),
            ]);
            outputs = vec![t("hv", &[nl], DType::F32)];
        }
        other => panic!("unknown entry template {other}"),
    }
    EntrySpec {
        name: entry.to_string(),
        file: dir.join(format!("{entry}.native.json")),
        inputs,
        outputs,
    }
}

// ---------------------------------------------------------------------------
// generation
// ---------------------------------------------------------------------------

fn write_blob(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes)
        .with_context(|| format!("writing {}", path.display()))
}

fn generate_into(dir: &Path) -> Result<()> {
    let defs = defs();
    let mut variants: BTreeMap<String, VariantSpec> = BTreeMap::new();

    for def in &defs {
        let vdir = dir.join(def.name);
        std::fs::create_dir_all(&vdir)
            .with_context(|| format!("creating {}", vdir.display()))?;
        let (nc, na, ns, nb) = def.sizes();
        write_blob(&vdir.join("init_theta_l.bin"), &def.init_theta_l())?;
        write_blob(&vdir.join("init_theta_s.bin"), &def.init_theta_s())?;
        let mut files = BTreeMap::new();
        files.insert(
            "init_theta_l".to_string(),
            PathBuf::from("init_theta_l.bin"),
        );
        files.insert(
            "init_theta_s".to_string(),
            PathBuf::from("init_theta_s.bin"),
        );
        if let Some(base) = def.frozen_base() {
            write_blob(&vdir.join("frozen_base.bin"), &base)?;
            files.insert(
                "frozen_base".to_string(),
                PathBuf::from("frozen_base.bin"),
            );
        }

        let mut entries = BTreeMap::new();
        for entry in def.entry_names() {
            let espec = entry_spec(def, entry, &vdir);
            std::fs::write(
                &espec.file,
                format!(
                    "{{\"engine\": \"{ENGINE_TAG}\", \"variant\": \"{}\", \
                     \"entry\": \"{entry}\"}}\n",
                    def.name
                ),
            )
            .with_context(|| format!("writing {}", espec.file.display()))?;
            entries.insert(entry.to_string(), espec);
        }

        variants.insert(
            def.name.to_string(),
            VariantSpec {
                name: def.name.to_string(),
                family: def.family().to_string(),
                task: def.task().to_string(),
                optimizer: "sgd".to_string(),
                opt_state: 0,
                batch: def.batch,
                eval_batch: def.eval_batch,
                x_shape: def.x_shape(),
                y_shape: def.y_shape(),
                smashed_shape: def.smashed_shape(),
                size_client: nc,
                size_aux: na,
                size_server: ns,
                size_base: nb,
                cost: def.cost(),
                entries,
                files,
                golden: BTreeMap::new(),
                dir: vdir.clone(),
            },
        );
    }

    // Execute every entry once with the canonical golden inputs and record
    // the digests — the same engine the tests run, so check_entry is a true
    // end-to-end determinism check.
    let pre = Manifest {
        variants,
        synth: Value::Null,
        root: dir.to_path_buf(),
    };
    let engine = Engine::new(&pre)?;
    let mut goldens: BTreeMap<String, BTreeMap<String, Vec<GoldenOutput>>> =
        BTreeMap::new();
    for (name, vspec) in &pre.variants {
        let mut per_entry = BTreeMap::new();
        for (ename, espec) in &vspec.entries {
            let mut inputs = Vec::with_capacity(espec.inputs.len());
            for (idx, spec) in espec.inputs.iter().enumerate() {
                inputs.push(
                    golden::golden_input_for(vspec, spec, idx, &vspec.task)
                        .with_context(|| format!("{name}/{ename} input"))?,
                );
            }
            let outs = engine
                .execute(vspec, espec, &inputs)
                .with_context(|| format!("golden run {name}/{ename}"))?;
            let mut recs = Vec::with_capacity(outs.len());
            for (out, ospec) in outs.iter().zip(&espec.outputs) {
                let (head, sum, l2, _len) = golden::digest(out);
                recs.push(GoldenOutput {
                    shape: ospec.shape.clone(),
                    head,
                    sum,
                    l2,
                });
            }
            per_entry.insert(ename.clone(), recs);
        }
        goldens.insert(name.clone(), per_entry);
    }

    let manifest_json = render_manifest(&pre, &goldens);
    std::fs::write(
        dir.join("manifest.json"),
        manifest_json.to_string_pretty(),
    )
    .context("writing manifest.json")?;
    Ok(())
}

fn tensor_json(s: &TensorSpec) -> Value {
    Value::obj(vec![
        ("name", Value::str(&s.name)),
        (
            "shape",
            Value::Arr(s.shape.iter().map(|&d| Value::Num(d as f64)).collect()),
        ),
        (
            "dtype",
            Value::str(match s.dtype {
                DType::F32 => "f32",
                DType::I32 => "i32",
            }),
        ),
    ])
}

fn render_manifest(
    m: &Manifest,
    goldens: &BTreeMap<String, BTreeMap<String, Vec<GoldenOutput>>>,
) -> Value {
    let mut vmap: BTreeMap<String, Value> = BTreeMap::new();
    for (name, v) in &m.variants {
        let usz = |n: usize| Value::Num(n as f64);
        let shape = |s: &Vec<usize>| {
            Value::Arr(s.iter().map(|&d| Value::Num(d as f64)).collect())
        };
        let c = &v.cost;
        let cost = Value::obj(vec![
            ("params_client", usz(c.params_client)),
            ("params_aux", usz(c.params_aux)),
            ("params_server", usz(c.params_server)),
            ("act_cache_client", usz(c.act_cache_client)),
            ("act_cache_aux", usz(c.act_cache_aux)),
            ("act_cache_server", usz(c.act_cache_server)),
            ("act_peak_client", usz(c.act_peak_client)),
            ("act_peak_aux", usz(c.act_peak_aux)),
            ("act_peak_server", usz(c.act_peak_server)),
            ("flops_fwd_client", usz(c.flops_fwd_client)),
            ("flops_fwd_aux", usz(c.flops_fwd_aux)),
            ("flops_fwd_server", usz(c.flops_fwd_server)),
            ("smashed_elems", usz(c.smashed_elems)),
            ("target_elems", usz(c.target_elems)),
        ]);
        let entries: BTreeMap<String, Value> = v
            .entries
            .iter()
            .map(|(en, e)| {
                let fname = e
                    .file
                    .file_name()
                    .map(|f| f.to_string_lossy().into_owned())
                    .unwrap_or_default();
                (
                    en.clone(),
                    Value::obj(vec![
                        ("file", Value::str(&fname)),
                        (
                            "inputs",
                            Value::Arr(
                                e.inputs.iter().map(tensor_json).collect(),
                            ),
                        ),
                        (
                            "outputs",
                            Value::Arr(
                                e.outputs.iter().map(tensor_json).collect(),
                            ),
                        ),
                    ]),
                )
            })
            .collect();
        let files: BTreeMap<String, Value> = v
            .files
            .iter()
            .map(|(k, p)| {
                (k.clone(), Value::str(&p.to_string_lossy()))
            })
            .collect();
        let golden: BTreeMap<String, Value> = goldens
            .get(name)
            .map(|per| {
                per.iter()
                    .map(|(en, recs)| {
                        let outs: Vec<Value> = recs
                            .iter()
                            .map(|g| {
                                Value::obj(vec![
                                    ("shape", shape(&g.shape)),
                                    ("head", Value::arr_f64(&g.head)),
                                    ("sum", Value::Num(g.sum)),
                                    ("l2", Value::Num(g.l2)),
                                ])
                            })
                            .collect();
                        (
                            en.clone(),
                            Value::obj(vec![("outputs", Value::Arr(outs))]),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();

        vmap.insert(
            name.clone(),
            Value::obj(vec![
                ("family", Value::str(&v.family)),
                ("task", Value::str(&v.task)),
                ("optimizer", Value::str(&v.optimizer)),
                ("opt_state", usz(v.opt_state)),
                ("batch", usz(v.batch)),
                ("eval_batch", usz(v.eval_batch)),
                ("x_shape", shape(&v.x_shape)),
                ("y_shape", shape(&v.y_shape)),
                ("smashed_shape", shape(&v.smashed_shape)),
                (
                    "sizes",
                    Value::obj(vec![
                        ("client", usz(v.size_client)),
                        ("aux", usz(v.size_aux)),
                        ("server", usz(v.size_server)),
                        ("base", usz(v.size_base)),
                    ]),
                ),
                ("cost", cost),
                ("entries", Value::Obj(entries)),
                ("files", Value::Obj(files)),
                ("golden", Value::Obj(golden)),
            ]),
        );
    }

    Value::obj(vec![
        ("generated_by", Value::str(ENGINE_TAG)),
        ("variants", Value::Obj(vmap)),
        ("synth", synth_goldens()),
    ])
}

/// Cross-generator pin points: digests of the shared deterministic streams,
/// checked by tests/golden.rs against the live Rust generators.
fn synth_goldens() -> Value {
    use crate::data::{synth_text, synth_vision};
    let labels: Vec<Value> = (0..32)
        .map(|i| Value::Num(synth_vision::label(42, i) as f64))
        .collect();
    let img = synth_vision::image(42, 0);
    let img_sum: f64 = img.iter().map(|&v| v as f64).sum();
    let img_first: Vec<f64> =
        img.iter().take(8).map(|&v| v as f64).collect();
    let tokens: Vec<Value> = synth_text::batch(42, 0, 1)
        .into_iter()
        .take(SEQ)
        .map(|t| Value::Num(t as f64))
        .collect();
    let gv: Vec<f64> = golden::golden_vec(8, 101)
        .into_iter()
        .map(|v| v as f64)
        .collect();
    Value::obj(vec![
        ("mix64_42_0", Value::str(&mix64(42, 0).to_string())),
        ("vision_labels_seed42", Value::Arr(labels)),
        ("vision_img0_sum", Value::Num(img_sum)),
        ("vision_img0_first", Value::arr_f64(&img_first)),
        ("text_record0", Value::str(&synth_text::record(42, 0))),
        ("text_tokens0", Value::Arr(tokens)),
        ("golden_vec8_salt101", Value::arr_f64(&gv)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defs_cover_required_variants() {
        let names: Vec<&str> = defs().iter().map(|d| d.name).collect();
        for required in [
            "cnn_c1",
            "cnn_c2",
            "gpt2nano_c1_a1",
            "gpt2micro_c2_a1",
            "gpt2nano_c1_a1_pallas",
        ] {
            assert!(names.contains(&required), "missing {required}");
        }
        assert!(names.len() >= 10);
    }

    #[test]
    fn cnn_c2_lacks_locked_entries() {
        let d = defs();
        let c2 = d.iter().find(|d| d.name == "cnn_c2").unwrap();
        assert!(!c2.entry_names().contains(&"server_step_cutgrad"));
        let c1 = d.iter().find(|d| d.name == "cnn_c1").unwrap();
        assert!(c1.entry_names().contains(&"server_step_cutgrad"));
        assert!(c1.entry_names().contains(&"hvp"));
    }

    #[test]
    fn sizes_are_consistent() {
        for d in defs() {
            let (nc, na, ns, nb) = d.sizes();
            assert!(nc > 0 && na > 0 && ns > 0);
            match d.arch {
                Arch::Vision { q } => {
                    assert_eq!(nc, 2 * q);
                    assert_eq!(nb, 0);
                }
                Arch::Lm { e, aux } => {
                    assert_eq!(nc, VOCAB * e);
                    assert_eq!(na, aux.size(e));
                    assert_eq!(nb, nc);
                }
            }
        }
    }

    #[test]
    fn engine_output_table_covers_every_entry() {
        // the stale-slot guard in Engine::execute_into only fires for
        // entries its table knows; every generated entry must be listed
        // with the exact output arity the spec declares
        for d in defs() {
            for entry in d.entry_names() {
                let e = entry_spec(&d, entry, Path::new("/tmp"));
                assert_eq!(
                    crate::runtime::native::produced_outputs(entry),
                    Some(e.outputs.len()),
                    "{}/{entry}: engine output table out of sync",
                    d.name
                );
            }
        }
    }

    #[test]
    fn entry_specs_have_positive_shapes() {
        for d in defs() {
            for entry in d.entry_names() {
                let e = entry_spec(&d, entry, Path::new("/tmp"));
                assert!(!e.inputs.is_empty() && !e.outputs.is_empty());
                for s in e.inputs.iter().chain(&e.outputs) {
                    assert!(s.elems() > 0, "{}/{}: {}", d.name, entry, s.name);
                }
            }
        }
    }
}
