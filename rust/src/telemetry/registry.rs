//! The global metrics registry: lock-free counters/gauges and
//! fixed-bucket histograms behind one typed namespace.
//!
//! Handles are `Arc`s obtained once (registration takes a short mutex on
//! the name map); every subsequent increment/observe is a relaxed atomic
//! op — safe to call from any worker, poll shard, or lane thread.
//! Counter *values* are therefore deterministic for a fixed workload
//! regardless of thread interleaving (pinned across 1/4/8 workers in
//! `rust/tests/telemetry.rs`).
//!
//! Histograms use power-of-two bucket bounds (microsecond-scaled on the
//! latency paths): bucket `i` covers `[2^i, 2^(i+1))` (bucket 0 covers
//! `[0, 2)`). Percentiles interpolate linearly inside the target bucket
//! — exact enough for a p50/p90/p99 latency table without storing raw
//! samples.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonically increasing event count.
#[derive(Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 value (stored as IEEE-754 bits in an AtomicU64).
#[derive(Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Number of power-of-two buckets: values up to 2^31 µs (~36 min) land
/// in a real bucket, larger ones clamp into the last.
pub const N_BUCKETS: usize = 32;

/// Fixed-bucket histogram with lock-free observation.
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: Vec<AtomicU64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
        }
    }
}

/// Bucket index for a value: floor(log2(v)), clamped; 0 and 1 share
/// bucket 0.
fn bucket_of(v: u64) -> usize {
    if v < 2 {
        return 0;
    }
    ((63 - v.leading_zeros()) as usize).min(N_BUCKETS - 1)
}

/// Inclusive-exclusive bounds `[lo, hi)` of bucket `i`.
fn bucket_bounds(i: usize) -> (f64, f64) {
    let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
    let hi = (1u64 << (i + 1)) as f64;
    (lo, hi)
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Percentile (`p` in [0, 1]) via linear interpolation inside the
    /// bucket where the cumulative count crosses `p * count`. Returns 0
    /// for an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * n as f64).max(1.0);
        let mut cum = 0u64;
        for i in 0..N_BUCKETS {
            let c = self.buckets[i].load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - cum as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
            cum += c;
        }
        // only reachable with concurrent observers racing the scan
        bucket_bounds(N_BUCKETS - 1).1
    }
}

// ---------------------------------------------------------------------------
// the registry
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    hists: BTreeMap<String, Arc<Histogram>>,
}

/// The process-global registry. All lookups go through the free
/// functions below.
pub struct Registry {
    inner: Mutex<Inner>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

fn global() -> &'static Registry {
    GLOBAL.get_or_init(|| Registry { inner: Mutex::new(Inner::default()) })
}

fn lock() -> std::sync::MutexGuard<'static, Inner> {
    global().inner.lock().unwrap_or_else(|p| p.into_inner())
}

/// Get-or-register a counter under `name` (e.g. `client.zo.probes`).
pub fn counter(name: &str) -> Arc<Counter> {
    let mut g = lock();
    g.counters.entry(name.to_string()).or_default().clone()
}

/// Get-or-register a gauge.
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut g = lock();
    g.gauges.entry(name.to_string()).or_default().clone()
}

/// Get-or-register a histogram (microsecond-scaled by convention:
/// `queue.wait_us`, `round.wall_us`, …).
pub fn histogram(name: &str) -> Arc<Histogram> {
    let mut g = lock();
    g.hists.entry(name.to_string()).or_default().clone()
}

/// One flat snapshot of every registered metric, plus the per-tag wire
/// counters. Histograms expand to `.count`, `.mean`, `.p50`, `.p90`,
/// `.p99`.
pub fn snapshot() -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    {
        let g = lock();
        for (k, c) in &g.counters {
            out.insert(k.clone(), c.get() as f64);
        }
        for (k, v) in &g.gauges {
            out.insert(k.clone(), v.get());
        }
        for (k, h) in &g.hists {
            out.insert(format!("{k}.count"), h.count() as f64);
            out.insert(format!("{k}.mean"), h.mean());
            out.insert(format!("{k}.p50"), h.percentile(0.50));
            out.insert(format!("{k}.p90"), h.percentile(0.90));
            out.insert(format!("{k}.p99"), h.percentile(0.99));
        }
    }
    crate::telemetry::wire_tags_into(&mut out);
    out
}

/// Merge the full snapshot into a run summary map (the
/// `RunRecord.summary` dump). Call sites gate on
/// [`crate::telemetry::metrics_enabled`] so flag-free runs emit
/// byte-identical output.
pub fn export_into(summary: &mut BTreeMap<String, f64>) {
    for (k, v) in snapshot() {
        summary.insert(k, v);
    }
}

/// Compact one-line rendering of the snapshot (`serve --stats_every N`).
/// Counters/gauges print as `k=v`; histograms as `k=p50/p99(count)`.
pub fn snapshot_line() -> String {
    let mut parts: Vec<String> = Vec::new();
    let g = lock();
    for (k, c) in &g.counters {
        parts.push(format!("{k}={}", c.get()));
    }
    for (k, v) in &g.gauges {
        let x = v.get();
        if x == x.trunc() && x.abs() < 1e15 {
            parts.push(format!("{k}={}", x as i64));
        } else {
            parts.push(format!("{k}={x:.3}"));
        }
    }
    for (k, h) in &g.hists {
        parts.push(format!(
            "{k}={:.0}/{:.0}us(n={})",
            h.percentile(0.50),
            h.percentile(0.99),
            h.count()
        ));
    }
    drop(g);
    let mut line = parts.join(" ");
    let mut wire = BTreeMap::new();
    crate::telemetry::wire_tags_into(&mut wire);
    let tx: f64 = wire
        .iter()
        .filter(|(k, _)| k.starts_with("net.tx.bytes."))
        .map(|(_, v)| *v)
        .sum();
    let rx: f64 = wire
        .iter()
        .filter(|(k, _)| k.starts_with("net.rx.bytes."))
        .map(|(_, v)| *v)
        .sum();
    if tx > 0.0 || rx > 0.0 {
        if !line.is_empty() {
            line.push(' ');
        }
        line.push_str(&format!("net.tx.bytes={tx:.0} net.rx.bytes={rx:.0}"));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = counter("test.reg.counter");
        c.add(5);
        c.inc();
        assert_eq!(c.get(), 6);
        // same name → same handle
        counter("test.reg.counter").add(4);
        assert_eq!(c.get(), 10);
        let g = gauge("test.reg.gauge");
        g.set(1.5);
        assert_eq!(g.get(), 1.5);
        let snap = snapshot();
        assert_eq!(snap["test.reg.counter"], 10.0);
        assert_eq!(snap["test.reg.gauge"], 1.5);
    }

    #[test]
    fn bucket_index_and_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_bounds(0), (0.0, 2.0));
        assert_eq!(bucket_bounds(3), (8.0, 16.0));
    }

    #[test]
    fn histogram_percentiles_interpolate() {
        let h = Histogram::default();
        // 100 values in [4, 8): bucket 2 holds all of them
        for _ in 0..100 {
            h.observe(5);
        }
        assert_eq!(h.count(), 100);
        // p50 target = 50th of 100 in [4,8): 4 + 0.5*4 = 6
        assert!((h.percentile(0.5) - 6.0).abs() < 1e-9);
        assert!((h.percentile(1.0) - 8.0).abs() < 1e-9);
        let empty = Histogram::default();
        assert_eq!(empty.percentile(0.99), 0.0);
    }

    #[test]
    fn snapshot_expands_histograms() {
        let h = histogram("test.reg.hist_us");
        h.observe(3);
        let snap = snapshot();
        assert!(snap.contains_key("test.reg.hist_us.count"));
        assert!(snap.contains_key("test.reg.hist_us.p99"));
        let line = snapshot_line();
        assert!(line.contains("test.reg.hist_us="), "{line}");
    }
}
