//! Span recording and Chrome trace-event export.
//!
//! Producers push fixed-size events into a per-thread ring (flight
//! recorder: when full, the oldest event is dropped and counted). A
//! single writer thread sweeps every ring ~20×/s and appends one JSON
//! event object per line to the `--trace_out` file.
//!
//! ## File format
//!
//! Chrome trace-event **JSON array format**: the first line is `[`,
//! every event line ends with a comma, and a clean shutdown writes a
//! final metadata event plus `]` — so a completed trace is strict JSON
//! (`json.loads` works), while a trace cut short by a crash is still
//! loadable by Perfetto / `chrome://tracing`, which tolerate the
//! missing bracket. `scripts/check_trace.py` validates both shapes.
//!
//! Events are pushed at span *end* (guard drop), so within one ring —
//! one `tid` — end timestamps (`ts + dur`) are monotone non-decreasing
//! in file order. Nested spans therefore close inner-first, exactly the
//! stacking Perfetto reconstructs.

use crate::util::json::{self, Value};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Events held per thread before the oldest is dropped (flight-recorder
/// semantics; the writer sweeps far faster than rings fill in practice).
const RING_CAP: usize = 1 << 13;

/// Writer sweep interval.
const SWEEP: std::time::Duration = std::time::Duration::from_millis(50);

/// One recorded event — integers and `&'static str`s only.
enum Ev {
    Complete {
        name: &'static str,
        ts: u64,
        dur: u64,
        k1: &'static str,
        v1: u64,
        k2: &'static str,
        v2: u64,
    },
    Instant {
        name: &'static str,
        ts: u64,
        k1: &'static str,
        v1: u64,
    },
}

struct RingInner {
    events: VecDeque<Ev>,
    dropped: u64,
    /// optional thread label; emitted once as a `thread_name` metadata
    /// event on the writer's next sweep
    label: Option<String>,
    label_emitted: bool,
}

struct Ring {
    tid: u64,
    inner: Mutex<RingInner>,
}

impl Ring {
    fn push(&self, ev: Ev) {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if g.events.len() >= RING_CAP {
            g.events.pop_front();
            g.dropped += 1;
        }
        g.events.push_back(ev);
    }
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: std::sync::OnceLock<Mutex<Vec<Arc<Ring>>>> =
        std::sync::OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static MY_RING: RefCell<Option<Arc<Ring>>> = const { RefCell::new(None) };
}

fn with_ring<F: FnOnce(&Ring)>(f: F) {
    MY_RING.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let ring = Arc::new(Ring {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                inner: Mutex::new(RingInner {
                    events: VecDeque::new(),
                    dropped: 0,
                    label: std::thread::current()
                        .name()
                        .map(|s| s.to_string()),
                    label_emitted: false,
                }),
            });
            rings()
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(ring.clone());
            *slot = Some(ring);
        }
        f(slot.as_ref().unwrap());
    });
}

pub(crate) fn record_complete(
    name: &'static str,
    ts: u64,
    dur: u64,
    k1: &'static str,
    v1: u64,
    k2: &'static str,
    v2: u64,
) {
    with_ring(|r| r.push(Ev::Complete { name, ts, dur, k1, v1, k2, v2 }));
}

pub(crate) fn record_instant(
    name: &'static str,
    ts: u64,
    k1: &'static str,
    v1: u64,
) {
    with_ring(|r| r.push(Ev::Instant { name, ts, k1, v1 }));
}

/// Label the calling thread in the trace (Perfetto track name) —
/// e.g. `"conn-shard-0"`, `"lane-3"`. No-op when spans are off.
pub fn set_thread_label(label: &str) {
    if !crate::telemetry::spans_enabled() {
        return;
    }
    with_ring(|r| {
        let mut g = r.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.label = Some(label.to_string());
        g.label_emitted = false;
    });
}

// ---------------------------------------------------------------------------
// the writer thread
// ---------------------------------------------------------------------------

struct WriterCtl {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<std::io::Result<std::fs::File>>,
}

fn writer_slot() -> &'static Mutex<Option<WriterCtl>> {
    static W: std::sync::OnceLock<Mutex<Option<WriterCtl>>> =
        std::sync::OnceLock::new();
    W.get_or_init(|| Mutex::new(None))
}

fn esc(s: &str) -> String {
    // names/labels are identifiers we control; Value::str handles the rest
    Value::str(s).to_string()
}

/// Append every buffered event to `out`. Returns events written.
fn drain_all(out: &mut impl std::io::Write) -> std::io::Result<u64> {
    let list: Vec<Arc<Ring>> =
        rings().lock().unwrap_or_else(|p| p.into_inner()).clone();
    let mut written = 0u64;
    for ring in list {
        let mut g = ring.inner.lock().unwrap_or_else(|p| p.into_inner());
        if !g.label_emitted {
            if let Some(label) = g.label.clone() {
                writeln!(
                    out,
                    "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"ts\":0,\
                     \"name\":\"thread_name\",\"args\":{{\"name\":{}}}}},",
                    ring.tid,
                    esc(&label),
                )?;
                g.label_emitted = true;
            }
        }
        while let Some(ev) = g.events.pop_front() {
            match ev {
                Ev::Complete { name, ts, dur, k1, v1, k2, v2 } => {
                    let mut args = String::new();
                    if !k1.is_empty() {
                        args.push_str(&format!("\"{k1}\":{v1}"));
                    }
                    if !k2.is_empty() {
                        if !args.is_empty() {
                            args.push(',');
                        }
                        args.push_str(&format!("\"{k2}\":{v2}"));
                    }
                    writeln!(
                        out,
                        "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{ts},\
                         \"dur\":{dur},\"name\":{},\"args\":{{{args}}}}},",
                        ring.tid,
                        esc(name),
                    )?;
                }
                Ev::Instant { name, ts, k1, v1 } => {
                    let args = if k1.is_empty() {
                        String::new()
                    } else {
                        format!("\"{k1}\":{v1}")
                    };
                    writeln!(
                        out,
                        "{{\"ph\":\"i\",\"pid\":1,\"tid\":{},\"ts\":{ts},\
                         \"s\":\"t\",\"name\":{},\"args\":{{{args}}}}},",
                        ring.tid,
                        esc(name),
                    )?;
                }
            }
            written += 1;
        }
    }
    Ok(written)
}

/// Install the trace writer: open `path` (creating parent dirs), start
/// the drain thread, and enable spans + metrics. Errors if a writer is
/// already installed.
pub fn install(path: &str, process_name: &str) -> Result<()> {
    let mut slot = writer_slot().lock().unwrap_or_else(|p| p.into_inner());
    if slot.is_some() {
        anyhow::bail!("trace writer already installed");
    }
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let mut file = std::fs::File::create(path)
        .with_context(|| format!("creating trace file {path}"))?;
    writeln!(file, "[")?;
    writeln!(
        file,
        "{{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"ts\":0,\
         \"name\":\"process_name\",\"args\":{{\"name\":{}}}}},",
        esc(process_name),
    )?;
    // pin the epoch before any span can fire
    let _ = crate::telemetry::epoch();
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::Builder::new()
        .name("telemetry-writer".into())
        .spawn(move || -> std::io::Result<std::fs::File> {
            let mut out = std::io::BufWriter::new(file);
            loop {
                std::thread::sleep(SWEEP);
                drain_all(&mut out)?;
                if stop2.load(Ordering::SeqCst) {
                    // final sweep after producers saw the disabled flag
                    drain_all(&mut out)?;
                    out.flush()?;
                    return out.into_inner().map_err(|e| e.into_error());
                }
            }
        })
        .context("spawning telemetry writer")?;
    *slot = Some(WriterCtl { stop, handle });
    drop(slot);
    crate::telemetry::enable_metrics();
    crate::telemetry::set_spans(true);
    Ok(())
}

/// Disable spans, drain every ring, close the JSON array, and join the
/// writer. Idempotent: a no-op when no writer is installed.
pub fn shutdown() -> Result<()> {
    let ctl = {
        let mut slot =
            writer_slot().lock().unwrap_or_else(|p| p.into_inner());
        slot.take()
    };
    let Some(ctl) = ctl else {
        return Ok(());
    };
    crate::telemetry::set_spans(false);
    ctl.stop.store(true, Ordering::SeqCst);
    let file = ctl
        .handle
        .join()
        .map_err(|_| anyhow::anyhow!("telemetry writer panicked"))?
        .context("telemetry writer I/O")?;
    let mut out = std::io::BufWriter::new(file);
    let dropped: u64 = rings()
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .iter()
        .map(|r| r.inner.lock().unwrap_or_else(|p| p.into_inner()).dropped)
        .sum();
    // last element carries no trailing comma, closing the strict array
    writeln!(
        out,
        "{{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"ts\":0,\
         \"name\":\"trace_done\",\"args\":{{\"dropped\":{dropped}}}}}",
    )?;
    writeln!(out, "]")?;
    out.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------------
// `heron-sfl report`: per-phase breakdown of a trace file
// ---------------------------------------------------------------------------

/// Parse one trace line into a JSON value, tolerating the array
/// scaffolding (`[`, `]`, trailing commas).
fn parse_line(line: &str) -> Option<Value> {
    let t = line.trim().trim_end_matches(',');
    if t.is_empty() || t == "[" || t == "]" {
        return None;
    }
    json::parse(t).ok()
}

/// Aggregated stats for one span name.
struct Phase {
    count: u64,
    total_us: f64,
    max_us: f64,
    hist: crate::telemetry::registry::Histogram,
}

/// Read a `--trace_out` file and print the per-phase time breakdown +
/// percentile table (`heron-sfl report t.jsonl`).
pub fn report(path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {path}"))?;
    let mut phases: std::collections::BTreeMap<String, Phase> =
        Default::default();
    let mut events = 0u64;
    let mut instants = 0u64;
    let mut tids = std::collections::BTreeSet::new();
    for line in text.lines() {
        let Some(v) = parse_line(line) else { continue };
        let ph = v.get("ph").and_then(Value::as_str).unwrap_or("");
        if let Some(t) = v.get("tid").and_then(Value::as_i64) {
            tids.insert(t);
        }
        match ph {
            "X" => {
                events += 1;
                let name = v
                    .get("name")
                    .and_then(Value::as_str)
                    .unwrap_or("?")
                    .to_string();
                let dur =
                    v.get("dur").and_then(Value::as_f64).unwrap_or(0.0);
                let p = phases.entry(name).or_insert_with(|| Phase {
                    count: 0,
                    total_us: 0.0,
                    max_us: 0.0,
                    hist: Default::default(),
                });
                p.count += 1;
                p.total_us += dur;
                p.max_us = p.max_us.max(dur);
                p.hist.observe(dur.max(0.0) as u64);
            }
            "i" => instants += 1,
            _ => {}
        }
    }
    if phases.is_empty() {
        anyhow::bail!("no complete events (ph:\"X\") in {path}");
    }
    let mut rows: Vec<(&String, &Phase)> = phases.iter().collect();
    rows.sort_by(|a, b| {
        b.1.total_us
            .partial_cmp(&a.1.total_us)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut t = crate::bench_harness::Table::new(&[
        "phase", "count", "total", "mean", "p50", "p90", "p99", "max",
    ]);
    let fmt = |us: f64| crate::bench_harness::fmt_ns(us * 1e3);
    for (name, p) in &rows {
        t.row(vec![
            (*name).clone(),
            p.count.to_string(),
            fmt(p.total_us),
            fmt(p.total_us / p.count as f64),
            fmt(p.hist.percentile(0.50)),
            fmt(p.hist.percentile(0.90)),
            fmt(p.hist.percentile(0.99)),
            fmt(p.max_us),
        ]);
    }
    t.print(&format!("per-phase time breakdown — {path}"));
    println!(
        "\n{events} span(s), {instants} instant event(s), {} thread track(s)",
        tids.len(),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_tolerates_scaffolding() {
        assert!(parse_line("[").is_none());
        assert!(parse_line("]").is_none());
        assert!(parse_line("").is_none());
        let v = parse_line(
            "{\"ph\":\"X\",\"pid\":1,\"tid\":3,\"ts\":10,\"dur\":5,\
             \"name\":\"x\",\"args\":{}},",
        )
        .unwrap();
        assert_eq!(v.get("ph").and_then(Value::as_str), Some("X"));
        assert_eq!(v.get("dur").and_then(Value::as_f64), Some(5.0));
    }

    #[test]
    fn install_record_shutdown_roundtrip() {
        let path = std::env::temp_dir().join(format!(
            "heron_trace_{}.jsonl",
            std::process::id()
        ));
        let p = path.to_str().unwrap();
        install(p, "unit-test").unwrap();
        assert!(crate::telemetry::spans_enabled());
        set_thread_label("test-thread");
        {
            let _s = crate::span!("unit_phase", client = 3u64, round = 1u64);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        crate::telemetry::instant("unit_instant", "wait_us", 42);
        shutdown().unwrap();
        assert!(!crate::telemetry::spans_enabled());
        let text = std::fs::read_to_string(p).unwrap();
        // strict JSON after a clean shutdown
        let v = json::parse(&text).expect("closed trace parses as JSON");
        let arr = v.as_arr().unwrap();
        assert!(arr.iter().any(|e| {
            e.get("name").and_then(Value::as_str) == Some("unit_phase")
                && e.get("ph").and_then(Value::as_str) == Some("X")
        }));
        assert!(arr.iter().any(|e| {
            e.get("name").and_then(Value::as_str) == Some("unit_instant")
        }));
        // report runs over it
        report(p).unwrap();
        // second install works after shutdown
        install(p, "unit-test-2").unwrap();
        shutdown().unwrap();
        let _ = std::fs::remove_file(p);
    }
}
