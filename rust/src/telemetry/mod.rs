//! Flight-recorder telemetry (substrate S28): spans, a unified metrics
//! registry, and Chrome-trace export — dependency-free (std only).
//!
//! Three pieces, one switchboard:
//!
//! * [`registry`] — a global namespace of lock-free atomic counters,
//!   gauges, and fixed-bucket histograms (p50/p90/p99 via bucket
//!   interpolation). The existing ad-hoc stats structs
//!   (`RuntimeStats`, `QueueStats`, `WireRoundStats`, `NetReport`)
//!   publish into it at finalize time, so every summary key flows
//!   through one typed namespace (`runtime.*`, `queue.*`, `net.*`,
//!   `eventsim.*`) and lands in `RunRecord.summary` /
//!   `bench_report.json` when telemetry is on.
//! * [`trace`] — span recording: per-thread ring buffers drained by a
//!   background writer thread into Chrome trace-event JSON
//!   (`--trace_out t.jsonl`, loadable in Perfetto / `chrome://tracing`),
//!   plus the `heron-sfl report` per-phase breakdown reader.
//! * this module — the [`span!`] macro, the shared monotonic clock the
//!   stderr logger also stamps from, and the two enable flags.
//!
//! ## The contract
//!
//! Instrumentation is **bit-invisible**: a span never touches an RNG,
//! never reads or writes a model float, and never reorders work — it
//! only reads the monotonic clock and pushes integers into a
//! thread-local ring. `rust/tests/telemetry.rs` pins traced == untraced
//! bit-identity for all five algorithms.
//!
//! It is also **near-free when disabled**: the off path of
//! [`Span::enter`] is a single relaxed [`AtomicBool`] load and a branch
//! — no clock read, no allocation (`telemetry_disabled_64k` in
//! `benches/perf_hotpath.rs` gates this at a multiple of the
//! stream-fill canary).
//!
//! Two independent switches:
//!
//! * **spans** (`spans_enabled`) — flipped by [`trace::install`] when a
//!   `--trace_out` writer exists to drain the rings;
//! * **metrics** (`metrics_enabled`) — flipped by [`enable_metrics`]
//!   (any telemetry flag: `--trace_out`, `--stats_every`); gates the
//!   per-message-tag wire counters and the registry dump into run
//!   summaries, so a no-flags run emits byte-identical output to a
//!   build that predates this module.

pub mod registry;
pub mod trace;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static SPANS_ON: AtomicBool = AtomicBool::new(false);
static METRICS_ON: AtomicBool = AtomicBool::new(false);

/// Are spans being recorded? One relaxed load — THE disabled-path cost.
#[inline(always)]
pub fn spans_enabled() -> bool {
    SPANS_ON.load(Ordering::Relaxed)
}

/// Is the metrics registry live (per-tag wire counters, summary dump)?
#[inline(always)]
pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Ordering::Relaxed)
}

/// Turn the metrics registry on (idempotent). `trace::install` calls
/// this too — spans imply metrics.
pub fn enable_metrics() {
    METRICS_ON.store(true, Ordering::SeqCst);
}

pub(crate) fn set_spans(on: bool) {
    SPANS_ON.store(on, Ordering::SeqCst);
}

// ---------------------------------------------------------------------------
// the shared clock
// ---------------------------------------------------------------------------

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The process-wide telemetry epoch. The stderr logger and every span
/// timestamp share it, so `[   3.21s I]` log lines line up with
/// `ts=3210000` trace events.
pub fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since [`epoch`] (the `ts` unit of Chrome trace events).
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

// ---------------------------------------------------------------------------
// spans
// ---------------------------------------------------------------------------

/// An open span: records a Chrome complete event (`ph:"X"`) on drop.
/// Construct via the [`span!`] macro. Holds only `&'static str`s and
/// integers — never floats, never RNG state.
pub struct Span {
    rec: Option<SpanStart>,
}

struct SpanStart {
    name: &'static str,
    k1: &'static str,
    v1: u64,
    k2: &'static str,
    v2: u64,
    t0: u64,
}

impl Span {
    /// Open a span. Disabled path: one relaxed load, no clock read.
    #[inline]
    pub fn enter(
        name: &'static str,
        k1: &'static str,
        v1: u64,
        k2: &'static str,
        v2: u64,
    ) -> Span {
        if !spans_enabled() {
            return Span { rec: None };
        }
        Span {
            rec: Some(SpanStart { name, k1, v1, k2, v2, t0: now_us() }),
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.rec.take() {
            let end = now_us();
            trace::record_complete(
                s.name,
                s.t0,
                end.saturating_sub(s.t0),
                s.k1,
                s.v1,
                s.k2,
                s.v2,
            );
        }
    }
}

/// Record an instant event (`ph:"i"`) — a point in time with one
/// integer annotation, e.g. a queue-wait observation stamped at pop.
#[inline]
pub fn instant(name: &'static str, k1: &'static str, v1: u64) {
    if !spans_enabled() {
        return;
    }
    trace::record_instant(name, now_us(), k1, v1);
}

/// Open a span over a code region; bind the guard (`let _s = span!(…)`)
/// so it closes at scope exit.
///
/// ```ignore
/// let _s = span!("local_phase", client = ci, round = r);
/// ```
///
/// Argument values are cast to `u64` — identifiers only, never model
/// state.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::telemetry::Span::enter($name, "", 0, "", 0)
    };
    ($name:expr, $k1:ident = $v1:expr) => {
        $crate::telemetry::Span::enter(
            $name,
            stringify!($k1),
            $v1 as u64,
            "",
            0,
        )
    };
    ($name:expr, $k1:ident = $v1:expr, $k2:ident = $v2:expr) => {
        $crate::telemetry::Span::enter(
            $name,
            stringify!($k1),
            $v1 as u64,
            stringify!($k2),
            $v2 as u64,
        )
    };
}

// ---------------------------------------------------------------------------
// per-message-tag wire accounting (`net.tx.bytes.{msg}` …)
// ---------------------------------------------------------------------------

/// One direction of per-tag traffic: bytes + frames per message tag.
struct TagCounters {
    bytes: Vec<AtomicU64>,
    frames: Vec<AtomicU64>,
}

impl TagCounters {
    fn new() -> Self {
        TagCounters {
            bytes: (0..N_TAGS).map(|_| AtomicU64::new(0)).collect(),
            frames: (0..N_TAGS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn note(&self, tag: u8, bytes: u64) {
        let i = (tag as usize).min(N_TAGS - 1);
        self.bytes[i].fetch_add(bytes, Ordering::Relaxed);
        self.frames[i].fetch_add(1, Ordering::Relaxed);
    }
}

/// Slots for message tags 1..=14 plus an "unknown" overflow slot.
const N_TAGS: usize = 16;

static WIRE_TX: OnceLock<TagCounters> = OnceLock::new();
static WIRE_RX: OnceLock<TagCounters> = OnceLock::new();

/// Account one sent frame under its message tag. Gated on
/// [`metrics_enabled`] so untraced runs pay one load.
#[inline]
pub fn note_tx(tag: u8, bytes: u64) {
    if !metrics_enabled() {
        return;
    }
    WIRE_TX.get_or_init(TagCounters::new).note(tag, bytes);
}

/// Account one received frame under its message tag.
#[inline]
pub fn note_rx(tag: u8, bytes: u64) {
    if !metrics_enabled() {
        return;
    }
    WIRE_RX.get_or_init(TagCounters::new).note(tag, bytes);
}

/// Human name for a wire message tag (`net::wire::Msg::tag` values).
pub fn tag_name(tag: u8) -> &'static str {
    match tag {
        1 => "hello",
        2 => "assign",
        3 => "round_barrier",
        4 => "model_sync",
        5 => "zo_update",
        6 => "smashed",
        7 => "cut_grad",
        8 => "align_grad",
        9 => "upload_ack",
        10 => "local_done",
        11 => "round_summary",
        12 => "shutdown",
        13 => "smashed_seq",
        14 => "seed_sync",
        _ => "unknown",
    }
}

/// Fold the per-tag wire counters into a snapshot map as
/// `net.tx.bytes.{msg}` / `net.tx.frames.{msg}` (+ `rx`), skipping
/// all-zero tags.
pub(crate) fn wire_tags_into(
    out: &mut std::collections::BTreeMap<String, f64>,
) {
    for (dir, cell) in [("tx", &WIRE_TX), ("rx", &WIRE_RX)] {
        if let Some(tc) = cell.get() {
            for tag in 0..N_TAGS {
                let b = tc.bytes[tag].load(Ordering::Relaxed);
                let f = tc.frames[tag].load(Ordering::Relaxed);
                if b == 0 && f == 0 {
                    continue;
                }
                let name = tag_name(tag as u8);
                out.insert(format!("net.{dir}.bytes.{name}"), b as f64);
                out.insert(format!("net.{dir}.frames.{name}"), f as f64);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_span_is_inert() {
        // entering/dropping must never panic, recorded or not (the flag
        // may be flipped by a concurrently running trace test)
        for i in 0..100u64 {
            let _s = crate::span!("inert", i = i);
        }
        instant("inert_i", "x", 1);
    }

    #[test]
    fn tag_names_cover_protocol() {
        for t in 1..=14u8 {
            assert_ne!(tag_name(t), "unknown", "tag {t} unnamed");
        }
        assert_eq!(tag_name(0), "unknown");
        assert_eq!(tag_name(99), "unknown");
    }

    #[test]
    fn clock_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }
}
