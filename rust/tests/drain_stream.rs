//! End-to-end tests of the `--drain stream` policy (pipelined mid-round
//! server consumption) against the default barrier drain:
//!
//! * **client-side invariance** — the decoupled client phase never reads
//!   θ_s, so θ_l, the per-step losses, and the analytic accounting are
//!   bit-identical across drain policies (HERON); eval metrics (which
//!   read θ_s) stay within tolerance;
//! * **degenerate determinism** — with one worker the arrival order *is*
//!   the Eq. (7) order, so stream is bit-identical to barrier outright;
//! * **latency win** — the event-sim's arrival-driven schedule reports a
//!   strictly lower server-side makespan for stream than for barrier
//!   whenever uploads land mid-round (`upload_every < local_steps`);
//! * **`--zo_wire seeds` composition** — the server-side replay reads
//!   only the round broadcast θ plus the client's own record, so the
//!   seeds trajectory is bit-identical across drain policies (the
//!   decision `RunConfig::validate` encodes);
//! * **typed rejection** — `stream` + a locked baseline fails validation
//!   with a downcastable [`DrainConfigError`], in-process and networked;
//! * **straggler cutoff edges** — `--round_deadline_ms` with zero
//!   surviving uploads finalizes the round empty (θ untouched, run
//!   continues); a deadline at/past the slowest lane cuts nobody and is
//!   bitwise identical to no deadline at all (the comparison is strict
//!   `>`); and the cutoff composes with `--drain stream` (mid-round
//!   consumed batches stand, cut θ never enters FedAvg).

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::{RunConfig, ZoWireMode};
use heron_sfl::coordinator::drain::{DrainConfigError, DrainMode};
use heron_sfl::coordinator::round::Driver;
use heron_sfl::metrics::RunRecord;
use heron_sfl::net::transport::{loopback_pair, Transport};
use heron_sfl::net::{run_client, serve_transports, NetReport};
use heron_sfl::runtime::Session;

mod common;
use common::with_session;

fn cfg(drain: DrainMode, workers: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 4,
        rounds: 2,
        local_steps: 4,
        upload_every: 2, // uploads land mid-round -> stream can overlap
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        workers,
        drain,
        ..Default::default()
    }
}

fn run(session: &Session, cfg: &RunConfig) -> (RunRecord, Vec<f32>, Vec<f32>) {
    let mut driver = Driver::new(session, cfg.clone()).unwrap();
    let rec = driver.run(cfg.drain.name()).unwrap();
    (rec, driver.theta_l.clone(), driver.theta_s.clone())
}

/// serve + N connect over in-memory loopback (clients on threads).
fn net_run(session: &Session, cfg: &RunConfig, n_conns: usize) -> NetReport {
    let mut server_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut client_ends = Vec::new();
    for _ in 0..n_conns {
        let (s, c) = loopback_pair();
        server_ends.push(Box::new(s));
        client_ends.push(c);
    }
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_transports(session, cfg.clone(), server_ends, "net")
        });
        let clients: Vec<_> = client_ends
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                scope.spawn(move || {
                    run_client(session, Box::new(c), &format!("edge-{i}"))
                })
            })
            .collect();
        let report = server.join().expect("server panicked").expect("server");
        for h in clients {
            h.join().expect("client panicked").expect("client");
        }
        report
    })
}

/// One worker: jobs run in participant order, so uploads arrive in
/// exactly the `(round, client, step)` order the barrier drain sorts
/// into — stream mode must then be bit-identical end to end, θ_s and
/// eval metrics included.
#[test]
fn stream_with_one_worker_is_bit_identical_to_barrier() {
    with_session(|s| {
        let (rec_b, tl_b, ts_b) = run(s, &cfg(DrainMode::Barrier, 1));
        let (rec_s, tl_s, ts_s) = run(s, &cfg(DrainMode::Stream, 1));
        assert_eq!(tl_b, tl_s, "θ_l");
        assert_eq!(ts_b, ts_s, "θ_s (arrival order degenerates to Eq. 7)");
        for (a, b) in rec_b.rounds.iter().zip(&rec_s.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
    });
}

/// Multi-worker stream: arrival order races, so θ_s may differ — but
/// everything the clients compute must not, and the eval metric stays
/// within tolerance of the barrier reference on the vision model.
#[test]
fn stream_multiworker_client_side_bit_identical_loss_within_tolerance() {
    with_session(|s| {
        let (rec_b, tl_b, _) = run(s, &cfg(DrainMode::Barrier, 4));
        let (rec_s, tl_s, _) = run(s, &cfg(DrainMode::Stream, 4));
        assert_eq!(tl_b, tl_s, "θ_l must not depend on the drain policy");
        for (a, b) in rec_b.rounds.iter().zip(&rec_s.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "train loss is client-side and θ_s-independent"
            );
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            assert!(
                (a.eval_metric - b.eval_metric).abs() < 0.05,
                "round {}: eval {} (barrier) vs {} (stream)",
                a.round,
                a.eval_metric,
                b.eval_metric
            );
            assert!(b.eval_metric.is_finite());
        }
        // summary invariants shared by both policies
        assert_eq!(
            rec_b.summary["comm_bytes"], rec_s.summary["comm_bytes"]
        );
        assert_eq!(
            rec_b.summary["client_flops"], rec_s.summary["client_flops"]
        );
        assert_eq!(
            rec_b.summary["queue_enqueued"],
            rec_s.summary["queue_enqueued"],
            "every upload is enqueued under either policy"
        );
        // mid-round consumption keeps the queue shallower: the per-round
        // high watermark can only shrink vs the hold-everything barrier
        assert_eq!(
            rec_b.summary["queue_max_depth"],
            (cfg(DrainMode::Barrier, 4).n_clients
                * (cfg(DrainMode::Barrier, 4).local_steps
                    / cfg(DrainMode::Barrier, 4).upload_every))
                as f64,
            "barrier holds the whole round's uploads"
        );
        assert!(
            rec_s.summary["queue_max_depth"]
                <= rec_b.summary["queue_max_depth"]
        );
        assert!(rec_s.summary["queue_hwm_mean"] >= 1.0);
    });
}

/// The latency claim, measured by the event-sim: with uploads landing
/// mid-round, the arrival-order schedule strictly beats the barrier
/// schedule every round — and the executed drain mode does not change
/// the simulated comparison (it is derived from the same arrivals).
#[test]
fn eventsim_reports_strictly_lower_stream_makespan() {
    with_session(|s| {
        for drain in [DrainMode::Barrier, DrainMode::Stream] {
            let (rec, _, _) = run(s, &cfg(drain, 2));
            assert!(
                rec.summary["server_makespan_stream_seconds"]
                    < rec.summary["server_makespan_barrier_seconds"],
                "{}: stream {} !< barrier {}",
                drain.name(),
                rec.summary["server_makespan_stream_seconds"],
                rec.summary["server_makespan_barrier_seconds"],
            );
            assert!(
                rec.summary["queue_wait_stream_seconds"]
                    < rec.summary["queue_wait_barrier_seconds"]
            );
        }
    });
}

/// `--drain stream` + `--zo_wire seeds`: the replay runs from the round
/// broadcast θ and the client's own record — never the smashed queue —
/// so the full seeds trajectory is preserved under stream drain
/// (client-side bitwise; θ_s keeps only the 1-worker pin).
#[test]
fn stream_composes_with_seeds_wire_mode_over_loopback() {
    with_session(|s| {
        let mut barrier = cfg(DrainMode::Barrier, 1);
        barrier.zo_wire = ZoWireMode::Seeds;
        barrier.n_pert = 2;
        let mut stream = barrier.clone();
        stream.drain = DrainMode::Stream;
        barrier.validate().unwrap();
        stream.validate().unwrap();
        let net_b = net_run(s, &barrier, 2);
        let net_s = net_run(s, &stream, 2);
        assert_eq!(
            net_b.final_theta_l, net_s.final_theta_l,
            "replayed θ_l must not depend on the drain policy"
        );
        for (a, b) in net_b.record.rounds.iter().zip(&net_s.record.rounds)
        {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            assert!((a.eval_metric - b.eval_metric).abs() < 0.05);
        }
        // the stream run actually pipelined: arrivals were recorded and
        // the simulated stream schedule beat the barrier schedule
        assert!(
            net_s.record.summary["server_makespan_stream_seconds"]
                < net_s.record.summary["server_makespan_barrier_seconds"]
        );
    });
}

/// `--drain stream` + `--zo_wire seed_agg`: the SeedSync roster is
/// assembled from the *absorbed* records at the round boundary — after
/// any drain policy has consumed the smashed queue — so the wire v7
/// broadcast and the client-side aggregate replay are drain-invariant
/// (client-side bitwise; θ_s keeps only the 1-worker pin).
#[test]
fn seed_agg_composes_with_stream_drain_over_loopback() {
    with_session(|s| {
        let mut barrier = cfg(DrainMode::Barrier, 1);
        barrier.zo_wire = ZoWireMode::SeedAgg;
        barrier.n_pert = 2;
        let mut stream = barrier.clone();
        stream.drain = DrainMode::Stream;
        barrier.validate().unwrap();
        stream.validate().unwrap();
        let net_b = net_run(s, &barrier, 2);
        let net_s = net_run(s, &stream, 2);
        assert_eq!(
            net_b.final_theta_l, net_s.final_theta_l,
            "aggregate-replayed θ_l must not depend on the drain policy"
        );
        for (a, b) in net_b.record.rounds.iter().zip(&net_s.record.rounds)
        {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            assert!((a.eval_metric - b.eval_metric).abs() < 0.05);
        }
    });
}

/// `--zo_wire seed_agg` across worker counts: under the barrier drain
/// the server absorbs outcomes in Eq. (7) order regardless of how many
/// client-phase workers raced, so the seed-space roster, the aggregated
/// θ_l, and the whole trajectory are bit-identical across 1/4/8
/// workers — θ_s and eval metrics included.
#[test]
fn seed_agg_bit_identical_across_worker_counts() {
    with_session(|s| {
        let mk = |workers| {
            let mut c = cfg(DrainMode::Barrier, workers);
            c.zo_wire = ZoWireMode::SeedAgg;
            c.n_pert = 2;
            c.validate().unwrap();
            c
        };
        let (rec1, tl1, ts1) = run(s, &mk(1));
        for workers in [4usize, 8] {
            let (rec, tl, ts) = run(s, &mk(workers));
            assert_eq!(tl1, tl, "{workers} workers: θ_l");
            assert_eq!(ts1, ts, "{workers} workers: θ_s");
            for (a, b) in rec1.rounds.iter().zip(&rec.rounds) {
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{workers} workers: train loss, round {}",
                    a.round
                );
                assert_eq!(
                    a.eval_metric.to_bits(),
                    b.eval_metric.to_bits(),
                    "{workers} workers: eval metric, round {}",
                    a.round
                );
                assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            }
        }
    });
}

/// Networked stream run: seq-tagged uploads are consumed between
/// events; the client-side trajectory still matches the in-process
/// barrier reference bit for bit (HERON), and wire traffic flows.
#[test]
fn net_stream_two_conns_client_side_matches_in_process() {
    with_session(|s| {
        let (rec_b, tl_b, _) = run(s, &cfg(DrainMode::Barrier, 1));
        let net = net_run(s, &cfg(DrainMode::Stream, 1), 2);
        assert_eq!(tl_b, net.final_theta_l, "θ_l");
        for (a, b) in rec_b.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            assert!((a.eval_metric - b.eval_metric).abs() < 0.05);
        }
        assert!(net.wire.bytes_sent > 0 && net.wire.bytes_recv > 0);
        assert_eq!(net.nacks_sent, 0);
        assert!(
            net.record.summary["server_makespan_stream_seconds"]
                < net.record.summary["server_makespan_barrier_seconds"],
            "SmashedSeq sent_at arrivals must drive the sim"
        );
    });
}

/// FSL-SAGE streams too: alignment feedback is generated mid-round from
/// the pipelined θ_s, and the aligned θ_l feeds the NEXT round — so
/// only the first round's losses are bit-comparable across policies
/// (the documented trade). The accounting (message counts, bytes) stays
/// deterministic throughout.
#[test]
fn fsl_sage_streams_with_mid_round_alignment() {
    with_session(|s| {
        let mut c = cfg(DrainMode::Stream, 2);
        c.algorithm = Algorithm::FslSage;
        c.align_every = 1;
        let (rec, _, _) = run(s, &c);
        let mut b = c.clone();
        b.drain = DrainMode::Barrier;
        let (rec_b, _, _) = run(s, &b);
        assert_eq!(rec.rounds.len(), rec_b.rounds.len());
        // round 0 starts from the same broadcast θ_l: losses bit-equal
        assert_eq!(
            rec.rounds[0].train_loss.to_bits(),
            rec_b.rounds[0].train_loss.to_bits()
        );
        for (x, y) in rec.rounds.iter().zip(&rec_b.rounds) {
            assert!(x.train_loss.is_finite());
            assert_eq!(
                x.comm_bytes_cum, y.comm_bytes_cum,
                "alignment message counts are order-independent"
            );
        }
    });
}

/// Deadline edge: a cutoff below even one message's RTT (1 ms virtual
/// vs the profile's 20 ms rtt floor) cuts every participant every
/// round. The round must still finalize — empty — and the run must
/// keep going: θ_l never moves (no θ entered FedAvg), the cut roster
/// is recorded per round, and the next round samples normally.
#[test]
fn deadline_cutting_everyone_finalizes_empty_rounds() {
    with_session(|s| {
        let mut c = cfg(DrainMode::Barrier, 2);
        c.round_deadline_ms = 1;
        c.validate().unwrap();
        let mut driver = Driver::new(s, c.clone()).unwrap();
        let init_theta = driver.theta_l.clone();
        let rec = driver.run("cut-all").unwrap();
        assert_eq!(rec.rounds.len(), c.rounds, "every round finalized");
        assert_eq!(driver.timings.len(), c.rounds);
        for t in &driver.timings {
            assert_eq!(
                t.cut_clients.len(),
                c.n_clients,
                "all participants cut at the deadline"
            );
        }
        assert_eq!(driver.theta_l, init_theta, "empty FedAvg leaves θ_l");
        for r in &rec.rounds {
            // mean over zero surviving losses is 0, never NaN
            assert!(r.train_loss.is_finite());
            assert!(r.eval_metric.is_finite());
        }
    });
}

/// Deadline edge: the cut comparison is strict (`>`), so the tightest
/// representable deadline at/above the slowest lane's finish time cuts
/// nobody — and the whole run stays **bitwise identical** to the
/// deadline-free reference (the bit-identity contract the flag must
/// preserve when it never fires).
#[test]
fn deadline_at_the_slowest_lane_cuts_nobody_and_stays_bitwise() {
    with_session(|s| {
        let base = cfg(DrainMode::Barrier, 2);
        let mut dref = Driver::new(s, base.clone()).unwrap();
        let rec_ref = dref.run("no-deadline").unwrap();
        let slowest = dref
            .timings
            .iter()
            .map(|t| t.client_phase)
            .fold(0.0f64, f64::max);
        let mut c = base.clone();
        c.round_deadline_ms = (slowest * 1e3).ceil() as u64;
        assert!(c.round_deadline_ms > 0);
        let mut d2 = Driver::new(s, c).unwrap();
        let rec2 = d2.run("deadline-edge").unwrap();
        assert_eq!(dref.theta_l, d2.theta_l, "θ_l");
        assert_eq!(dref.theta_s, d2.theta_s, "θ_s");
        for (a, b) in rec_ref.rounds.iter().zip(&rec2.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
        for t in &d2.timings {
            assert!(t.cut_clients.is_empty(), "strict > cuts nobody at the edge");
        }
    });
}

/// Deadline × `--drain stream`: a deadline that never fires leaves the
/// stream run bitwise untouched, and an aggressive one composes with
/// pipelined consumption — batches the server already consumed
/// mid-round stand (θ_s is allowed to have moved), but a cut client's θ
/// never enters FedAvg and the run completes every round.
#[test]
fn stream_drain_composes_with_the_deadline_cutoff() {
    with_session(|s| {
        let mut quiet = cfg(DrainMode::Stream, 1);
        quiet.round_deadline_ms = 3_600_000; // 1h virtual: never fires
        let (rec_q, tl_q, ts_q) = run(s, &quiet);
        let (rec_0, tl_0, ts_0) = run(s, &cfg(DrainMode::Stream, 1));
        assert_eq!(tl_q, tl_0, "unfired deadline must not perturb θ_l");
        assert_eq!(ts_q, ts_0, "unfired deadline must not perturb θ_s");
        for (a, b) in rec_q.rounds.iter().zip(&rec_0.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
        }

        let mut hard = cfg(DrainMode::Stream, 2);
        hard.round_deadline_ms = 1;
        let mut d = Driver::new(s, hard.clone()).unwrap();
        let init = d.theta_l.clone();
        let rec = d.run("stream-cut").unwrap();
        assert_eq!(rec.rounds.len(), hard.rounds);
        for t in &d.timings {
            assert_eq!(
                t.cut_clients,
                (0..hard.n_clients).collect::<Vec<_>>(),
                "sorted cut roster covers the whole cohort"
            );
        }
        assert_eq!(d.theta_l, init, "cut θ never enters FedAvg");
    });
}

/// The typed rejection, both directions: locked baselines cannot
/// stream (in-process and networked construction paths), while every
/// decoupled algorithm can.
#[test]
fn locked_baselines_reject_stream_with_typed_error() {
    with_session(|s| {
        for alg in [Algorithm::SflV1, Algorithm::SflV2] {
            let mut c = cfg(DrainMode::Stream, 1);
            c.algorithm = alg;
            let err = Driver::new(s, c.clone()).err().expect("must reject");
            let typed = err
                .downcast_ref::<DrainConfigError>()
                .expect("DrainConfigError");
            assert_eq!(typed.algorithm, alg.name());
            // the networked dispatcher validates the same config
            let (srv, _cli) = loopback_pair();
            let res = serve_transports(
                s,
                c,
                vec![Box::new(srv) as Box<dyn Transport>],
                "reject",
            );
            assert!(
                res.err()
                    .expect("serve must reject")
                    .downcast_ref::<DrainConfigError>()
                    .is_some(),
                "{}: serve path must carry the typed error",
                alg.name()
            );
        }
        for alg in [Algorithm::Heron, Algorithm::CseFsl, Algorithm::FslSage]
        {
            let mut c = cfg(DrainMode::Stream, 1);
            c.algorithm = alg;
            c.validate().unwrap();
        }
    });
}
