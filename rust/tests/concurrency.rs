//! Parallel round-engine tests: bit-determinism across worker counts, and
//! the concurrent Main-Server queue's stats/backpressure under contention.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::coordinator::server_queue::{ServerQueue, SmashedBatch};

mod common;
use common::with_session;

fn cfg(alg: Algorithm, workers: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: alg,
        n_clients: 6,
        rounds: 2,
        local_steps: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        workers,
        ..Default::default()
    }
}

/// The round outputs a run produces, captured for bitwise comparison.
fn run_fingerprint(alg: Algorithm, workers: usize) -> (Vec<f32>, Vec<f32>, Vec<f64>, Vec<f64>, u64) {
    with_session(|s| {
        let mut driver = Driver::new(s, cfg(alg, workers)).unwrap();
        let rec = driver.run(&format!("{}x{workers}", alg.name())).unwrap();
        let losses: Vec<f64> =
            rec.rounds.iter().map(|r| r.train_loss).collect();
        let metrics: Vec<f64> =
            rec.rounds.iter().map(|r| r.eval_metric).collect();
        (
            driver.theta_l.clone(),
            driver.theta_s.clone(),
            losses,
            metrics,
            driver.comm_bytes,
        )
    })
}

#[test]
fn heron_bit_identical_across_worker_counts() {
    let base = run_fingerprint(Algorithm::Heron, 1);
    for workers in [4, 8] {
        let other = run_fingerprint(Algorithm::Heron, workers);
        assert_eq!(base.0, other.0, "theta_l differs at workers={workers}");
        assert_eq!(base.1, other.1, "theta_s differs at workers={workers}");
        assert_eq!(base.2, other.2, "losses differ at workers={workers}");
        assert_eq!(base.3, other.3, "metrics differ at workers={workers}");
        assert_eq!(base.4, other.4, "comm differs at workers={workers}");
    }
}

#[test]
fn fo_baselines_bit_identical_across_worker_counts() {
    for alg in [Algorithm::CseFsl, Algorithm::FslSage] {
        let a = run_fingerprint(alg, 1);
        let b = run_fingerprint(alg, 8);
        assert_eq!(a.0, b.0, "{}: theta_l differs", alg.name());
        assert_eq!(a.1, b.1, "{}: theta_s differs", alg.name());
        assert_eq!(a.2, b.2, "{}: losses differ", alg.name());
    }
}

#[test]
fn auto_workers_matches_explicit() {
    // workers = 0 resolves to available cores; results must still be
    // bit-identical to the sequential run
    let a = run_fingerprint(Algorithm::Heron, 1);
    let b = run_fingerprint(Algorithm::Heron, 0);
    assert_eq!(a.0, b.0);
    assert_eq!(a.2, b.2);
}

#[test]
fn queue_stats_flow_into_run_summary() {
    with_session(|s| {
        let mut driver = Driver::new(s, cfg(Algorithm::Heron, 4)).unwrap();
        let rec = driver.run("queue-stats").unwrap();
        // 6 clients x 2 uploads x 2 rounds
        assert_eq!(rec.summary["queue_enqueued"], 24.0);
        assert_eq!(rec.summary["queue_dropped"], 0.0);
        assert!(rec.summary["queue_max_depth"] >= 1.0);
        assert!(rec.summary["host_makespan_seconds"] > 0.0);
    })
}

// ---------------------------------------------------------------------------
// ServerQueue under concurrent producers
// ---------------------------------------------------------------------------

fn batch(client: usize, round: usize, step: usize) -> SmashedBatch {
    SmashedBatch {
        client,
        round,
        step,
        smashed: vec![client as f32; 8],
        targets: vec![step as i32],
    }
}

#[test]
fn concurrent_enqueue_backpressure_and_drop_stats() {
    let q = ServerQueue::new(50);
    let accepted: usize = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let q = &q;
                s.spawn(move || {
                    let mut ok = 0usize;
                    for i in 0..25 {
                        if q.push(batch(t, 0, i)) {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    let st = q.stats();
    assert_eq!(accepted, 50, "bounded queue must accept exactly capacity");
    assert_eq!(st.enqueued, 50);
    assert_eq!(st.dropped, 200 - 50);
    assert_eq!(st.max_depth, 50);
    assert_eq!(q.len(), 50);
}

#[test]
fn concurrent_enqueue_drains_deterministically() {
    // whatever the producer interleaving, the barrier drain is sorted
    let run = || {
        let q = ServerQueue::new(1024);
        std::thread::scope(|s| {
            for t in 0..6 {
                let q = &q;
                s.spawn(move || {
                    for step in 1..=8 {
                        q.push(batch(t, 3, step));
                    }
                });
            }
        });
        q.drain_sorted()
            .iter()
            .map(|b| (b.round, b.client, b.step))
            .collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert_eq!(a.len(), 48);
    let mut sorted = a.clone();
    sorted.sort();
    assert_eq!(a, sorted, "drain order must be (round, client, step)");
}

#[test]
fn interleaved_push_pop_conserves_counts() {
    let q = ServerQueue::new(16);
    std::thread::scope(|s| {
        for t in 0..4 {
            let q = &q;
            s.spawn(move || {
                for i in 0..64 {
                    q.push(batch(t, 0, i));
                    if i % 3 == 0 {
                        q.pop();
                    }
                }
            });
        }
    });
    let st = q.stats();
    assert_eq!(
        st.enqueued,
        st.processed + q.len() as u64,
        "every accepted batch is either processed or still queued"
    );
    assert!(st.max_depth <= 16);
}
