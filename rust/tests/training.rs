//! End-to-end training integration tests: a few rounds of each algorithm on
//! the real artifacts, asserting the optimization signal and the accounting
//! invariants. Requires `make artifacts`.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::data::partition::Scheme;
mod common;
use common::with_session;

fn quick_cfg(alg: Algorithm) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: alg,
        n_clients: 3,
        rounds: 4,
        local_steps: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        ..Default::default()
    }
}

fn train_loss_drops(alg: Algorithm) {
    let rec = with_session(|s| {
        let mut driver = Driver::new(s, quick_cfg(alg)).unwrap();
        driver.run(alg.name()).unwrap()
    });
    let first = rec.rounds.first().unwrap().train_loss;
    let last = rec.rounds.last().unwrap().train_loss;
    assert!(
        last < first,
        "{}: loss did not drop ({first:.4} -> {last:.4})",
        alg.name()
    );
    // comm accounting is monotone and positive
    let mut prev = 0;
    for r in &rec.rounds {
        assert!(r.comm_bytes_cum > prev);
        prev = r.comm_bytes_cum;
    }
}

#[test]
fn heron_trains() {
    train_loss_drops(Algorithm::Heron);
}

#[test]
fn cse_fsl_trains() {
    train_loss_drops(Algorithm::CseFsl);
}

#[test]
fn fsl_sage_trains() {
    train_loss_drops(Algorithm::FslSage);
}

#[test]
fn sfl_v2_trains() {
    train_loss_drops(Algorithm::SflV2);
}

#[test]
fn sfl_v1_trains() {
    train_loss_drops(Algorithm::SflV1);
}

#[test]
fn heron_lm_finetunes() {
    let cfg = RunConfig {
        variant: "gpt2nano_c1_a1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 2,
        rounds: 3,
        local_steps: 2,
        lr_client: 1e-3,
        lr_server: 1e-3,
        mu: 1e-2,
        dataset_size: 512,
        eval_every: 1,
        ..Default::default()
    };
    let rec = with_session(|s| {
        let mut driver = Driver::new(s, cfg).unwrap();
        driver.run("lm").unwrap()
    });
    // the style-0-pretrained base starts high on the style-1 task and LoRA
    // fine-tuning must bring perplexity down (the Fig 5 domain-shift story)
    let ppl: Vec<f64> = rec
        .rounds
        .iter()
        .filter(|r| r.eval_metric.is_finite())
        .map(|r| r.eval_metric)
        .collect();
    assert!(
        ppl.iter().all(|&p| p.is_finite() && p > 1.0),
        "ppl {ppl:?}"
    );
    assert!(
        *ppl.first().unwrap() > 50.0,
        "domain shift missing: initial ppl {ppl:?}"
    );
    assert!(
        *ppl.last().unwrap() < ppl.first().unwrap() * 0.95,
        "fine-tuning made no progress: {ppl:?}"
    );
}

#[test]
fn runs_are_deterministic() {
    let run = || {
        with_session(|s| {
            let mut driver =
                Driver::new(s, quick_cfg(Algorithm::Heron)).unwrap();
            let rec = driver.run("det").unwrap();
            (
                rec.rounds.last().unwrap().train_loss,
                rec.rounds.last().unwrap().eval_metric,
            )
        })
    };
    let (l1, m1) = run();
    let (l2, m2) = run();
    assert_eq!(l1, l2, "train loss not reproducible");
    assert_eq!(m1, m2, "eval metric not reproducible");
}

#[test]
fn partial_participation_and_noniid() {
    let mut cfg = quick_cfg(Algorithm::Heron);
    cfg.n_clients = 6;
    cfg.participation = 0.5;
    cfg.scheme = Scheme::Dirichlet { alpha: 0.3 };
    let rec = with_session(|s| {
        let mut driver = Driver::new(s, cfg).unwrap();
        driver.run("pp").unwrap()
    });
    assert_eq!(rec.rounds.len(), 4);
    assert!(rec.rounds.last().unwrap().train_loss.is_finite());
}

#[test]
fn heron_comm_le_cse_comm() {
    // identical protocol schedule => identical smashed uploads; HERON must
    // not add communication (paper's central comm claim)
    let run = |alg| {
        with_session(|s| {
            let mut driver = Driver::new(s, quick_cfg(alg)).unwrap();
            driver.run("comm").unwrap().summary["comm_bytes"]
        })
    };
    let heron = run(Algorithm::Heron);
    let cse = run(Algorithm::CseFsl);
    assert_eq!(heron, cse, "HERON comm {heron} != CSE comm {cse}");
}

#[test]
fn sflv2_comm_exceeds_decoupled() {
    let run = |alg| {
        with_session(|s| {
            let mut driver = Driver::new(s, quick_cfg(alg)).unwrap();
            driver.run("comm2").unwrap().summary["comm_bytes"]
        })
    };
    assert!(run(Algorithm::SflV2) > run(Algorithm::Heron));
}

#[test]
fn training_lock_shows_in_virtual_time() {
    let run = |alg| {
        with_session(|s| {
            let mut driver = Driver::new(s, quick_cfg(alg)).unwrap();
            driver.run("lock").unwrap().summary["client_idle_seconds"]
        })
    };
    let locked = run(Algorithm::SflV2);
    let decoupled = run(Algorithm::Heron);
    assert!(
        locked > decoupled,
        "SFLV2 idle {locked} should exceed HERON idle {decoupled}"
    );
}

#[test]
fn n_pert_scaling_changes_flops_not_comm() {
    let run = |np| {
        with_session(|s| {
            let mut cfg = quick_cfg(Algorithm::Heron);
            cfg.n_pert = np;
            let mut driver = Driver::new(s, cfg).unwrap();
            let rec = driver.run("np").unwrap();
            (rec.summary["client_flops"], rec.summary["comm_bytes"])
        })
    };
    let (f1, c1) = run(1);
    let (f4, c4) = run(4);
    assert!(f4 > f1 * 2.0, "flops must scale with probes");
    assert_eq!(c1, c4, "ZO probes must not add communication");
}

#[test]
fn rejects_missing_entries() {
    // cnn_c2 lacks server_step_cutgrad -> SFLV2 must be rejected up front
    let mut cfg = quick_cfg(Algorithm::SflV2);
    cfg.variant = "cnn_c2".into();
    with_session(|s| assert!(Driver::new(s, cfg).is_err()));
}

#[test]
fn rejects_invalid_config() {
    let mut cfg = quick_cfg(Algorithm::Heron);
    cfg.mu = 0.0;
    with_session(|s| assert!(Driver::new(s, cfg).is_err()));
}
