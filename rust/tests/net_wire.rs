//! Property tests for the `heron-net` wire codec (`util::prop` substrate):
//! encode/decode roundtrip for every message type under random contents,
//! and *rejection — never a panic* on truncated frames, corrupted bytes,
//! bad checksums, and unknown version/message tags.

use heron_sfl::net::wire::{
    self, decode_frame, encode_frame, Msg, WireError, MAX_PAYLOAD, VERSION,
};
use heron_sfl::util::prop::{self, Gen};

fn arb_string(g: &mut Gen) -> String {
    let n = g.usize_in(0..24);
    (0..n)
        .map(|_| {
            // printable ascii plus some multibyte utf8
            match g.usize_in(0..20) {
                0 => 'λ',
                1 => '†',
                _ => (g.usize_in(0x20..0x7f) as u8) as char,
            }
        })
        .collect()
}

fn arb_f32s(g: &mut Gen, max: usize) -> Vec<f32> {
    g.vec_f32(0..max, -1e6..1e6)
}

fn arb_i32s(g: &mut Gen, max: usize) -> Vec<i32> {
    let n = g.usize_in(0..max);
    (0..n).map(|_| g.u64() as i32).collect()
}

fn arb_u32s(g: &mut Gen, max: usize) -> Vec<u32> {
    let n = g.usize_in(0..max);
    (0..n).map(|_| g.u64() as u32).collect()
}

/// Raw bytes — v6 payload envelopes (smashed / cut-gradient) are opaque
/// codec output at the wire layer, so any byte string must roundtrip.
fn arb_u8s(g: &mut Gen, max: usize) -> Vec<u8> {
    let n = g.usize_in(0..max);
    (0..n).map(|_| g.u64() as u8).collect()
}

fn arb_f64s(g: &mut Gen, max: usize) -> Vec<f64> {
    let n = g.usize_in(0..max);
    (0..n).map(|_| g.f64_in(-1e6..1e6)).collect()
}

/// One random message of a random type.
fn arb_msg(g: &mut Gen) -> Msg {
    match g.usize_in(0..14) {
        0 => Msg::Hello {
            name: arb_string(g),
            protocol: g.u64() as u32,
            lanes: g.u64() as u32,
            codecs: arb_u8s(g, 8),
        },
        1 => Msg::Assign {
            lane: g.u64() as u32,
            client_ids: arb_u32s(g, 16),
            config: arb_string(g),
            rejoin_round: g.u64() as u32,
            phases: arb_u32s(g, 16),
        },
        2 => Msg::RoundBarrier {
            round: g.u64() as u32,
            participants: arb_u32s(g, 16),
        },
        3 => Msg::ModelSync {
            lane: g.u64() as u32,
            round: g.u64() as u32,
            client: g.u64() as u32,
            theta: arb_f32s(g, 256),
        },
        4 => Msg::ZoUpdate {
            lane: g.u64() as u32,
            client: g.u64() as u32,
            round: g.u64() as u32,
            seeds: arb_i32s(g, 32),
            scalars: arb_f32s(g, 32),
            gscales: arb_f32s(g, 64),
        },
        5 => Msg::Smashed {
            lane: g.u64() as u32,
            client: g.u64() as u32,
            round: g.u64() as u32,
            step: g.u64() as u32,
            smashed: arb_u8s(g, 1024),
            targets: arb_i32s(g, 64),
        },
        6 => Msg::CutGrad {
            client: g.u64() as u32,
            round: g.u64() as u32,
            step: g.u64() as u32,
            loss: g.f32_in(-100.0..100.0),
            g: arb_u8s(g, 1024),
        },
        7 => Msg::AlignGrad {
            client: g.u64() as u32,
            round: g.u64() as u32,
            g: arb_f32s(g, 256),
        },
        8 => Msg::UploadAck {
            client: g.u64() as u32,
            round: g.u64() as u32,
            step: g.u64() as u32,
            accepted: g.bool(),
            reason: arb_string(g),
        },
        9 => Msg::LocalDone {
            lane: g.u64() as u32,
            client: g.u64() as u32,
            round: g.u64() as u32,
            comm_bytes: g.u64(),
            flops: g.u64(),
            lane_time: g.f64_in(0.0..1e6),
            lane_idle: g.f64_in(0.0..1e6),
        },
        10 => Msg::RoundSummary {
            round: g.u64() as u32,
            train_loss: g.f64_in(-10.0..10.0),
            comm_bytes: g.u64(),
            wire_bytes: g.u64(),
        },
        11 => Msg::SmashedSeq {
            lane: g.u64() as u32,
            client: g.u64() as u32,
            round: g.u64() as u32,
            step: g.u64() as u32,
            seq: g.u64() as u32,
            sent_at: g.f64_in(0.0..1e6),
            smashed: arb_u8s(g, 1024),
            targets: arb_i32s(g, 64),
        },
        // v7: shape consistency between the four vectors is the
        // *receiver's* replay-time contract, not the codec's — any
        // lengths must roundtrip
        12 => Msg::SeedSync {
            round: g.u64() as u32,
            clients: arb_u32s(g, 16),
            weights: arb_f64s(g, 16),
            seeds: arb_i32s(g, 64),
            gscales: arb_f32s(g, 128),
        },
        _ => Msg::Shutdown { reason: arb_string(g) },
    }
}

#[test]
fn roundtrip_every_message_type() {
    prop::check(400, |g| {
        let msg = arb_msg(g);
        let frame = encode_frame(&msg);
        let (back, used) = decode_frame(&frame)
            .map_err(|e| format!("{}: decode failed: {e}", msg.name()))?;
        prop::assert_prop!(used == frame.len(), "{}: partial consume", msg.name());
        prop::assert_prop!(back == msg, "{}: roundtrip mismatch", msg.name());
        // trailing bytes after a complete frame are the next frame's
        // problem — decode must report the exact boundary
        let mut stream = frame.clone();
        stream.extend_from_slice(&frame);
        let (_, used2) =
            decode_frame(&stream).map_err(|e| format!("concat: {e}"))?;
        prop::assert_prop!(used2 == frame.len(), "boundary detection");
        Ok(())
    });
}

#[test]
fn nonfinite_payloads_roundtrip_bitwise() {
    // NaN != NaN under PartialEq, so compare re-encoded bytes instead:
    // the codec must preserve f32/f64 bit patterns exactly.
    for bits in [0x7FC0_0001u32, 0x7F80_0000, 0xFF80_0000, 0x0000_0001] {
        let msg = Msg::ModelSync {
            lane: 0,
            round: 0,
            client: 1,
            theta: vec![f32::from_bits(bits), 1.0],
        };
        let frame = encode_frame(&msg);
        let (back, _) = decode_frame(&frame).unwrap();
        assert_eq!(encode_frame(&back), frame, "bits {bits:08x}");
    }
}

#[test]
fn truncation_always_rejected_never_panics() {
    prop::check(300, |g| {
        let msg = arb_msg(g);
        let frame = encode_frame(&msg);
        let cut = g.usize_in(0..frame.len());
        match decode_frame(&frame[..cut]) {
            Err(WireError::Truncated) => Ok(()),
            Err(e) => Err(format!("{}: cut {cut} gave {e}", msg.name())),
            Ok(_) => Err(format!("{}: truncated frame decoded", msg.name())),
        }
    });
}

#[test]
fn random_single_byte_corruption_is_rejected() {
    prop::check(400, |g| {
        let msg = arb_msg(g);
        let mut frame = encode_frame(&msg);
        let pos = g.usize_in(0..frame.len());
        let flip = (g.usize_in(1..256)) as u8; // never a no-op
        frame[pos] ^= flip;
        // decode must never panic; CRC-32 catches any single-byte flip
        // that survives the structural header checks
        prop::assert_prop!(
            decode_frame(&frame).is_err(),
            "{}: flip {flip:#x} at {pos} went undetected",
            msg.name()
        );
        Ok(())
    });
}

#[test]
fn random_garbage_never_panics() {
    prop::check(500, |g| {
        let n = g.usize_in(0..200);
        let bytes: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
        let _ = decode_frame(&bytes); // outcome irrelevant; must not panic
        let mut cur = std::io::Cursor::new(bytes);
        let _ = wire::read_frame(&mut cur);
        Ok(())
    });
}

#[test]
fn unknown_version_and_tag_are_typed_errors() {
    let frame = encode_frame(&Msg::Shutdown { reason: "x".into() });
    for v in (0..=255u8).filter(|&v| v != VERSION) {
        let mut f = frame.clone();
        f[2] = v;
        assert_eq!(decode_frame(&f).unwrap_err(), WireError::BadVersion(v));
    }
    for tag in [0u8, 15, 42, 255] {
        let mut f = frame.clone();
        f[3] = tag;
        assert_eq!(decode_frame(&f).unwrap_err(), WireError::BadTag(tag));
    }
}

#[test]
fn hostile_length_fields_do_not_allocate_or_panic() {
    // outer length: larger than the cap
    let frame = encode_frame(&Msg::Hello {
        name: "h".into(),
        protocol: 1,
        lanes: 1,
        codecs: heron_sfl::net::codec::SUPPORTED.to_vec(),
    });
    let mut f = frame.clone();
    f[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    assert_eq!(
        decode_frame(&f).unwrap_err(),
        WireError::TooLarge(MAX_PAYLOAD + 1)
    );
    // inner vector length: claims 1 GiB of f32s inside a tiny payload;
    // must be rejected by the pre-allocation bound check (as Malformed),
    // not by an OOM or a checksum-only failure. Build the frame by hand
    // with a correct CRC so the length check is what trips.
    let mut payload = Vec::new();
    payload.extend_from_slice(&0u32.to_le_bytes()); // lane
    payload.extend_from_slice(&3u32.to_le_bytes()); // round
    payload.extend_from_slice(&7u32.to_le_bytes()); // client
    payload.extend_from_slice(&(1u32 << 28).to_le_bytes()); // theta len (!)
    let mut f = Vec::new();
    f.extend_from_slice(&wire::MAGIC);
    f.push(VERSION);
    f.push(4); // ModelSync
    f.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    f.extend_from_slice(&payload);
    let crc = wire::crc32(&f);
    f.extend_from_slice(&crc.to_le_bytes());
    assert!(matches!(
        decode_frame(&f).unwrap_err(),
        WireError::Malformed(_)
    ));
}
