//! Telemetry integration tests: the observability layer must be
//! *provably invisible* — traced and untraced runs produce bit-identical
//! trajectories for every algorithm — while the registry stays
//! deterministic across worker counts and the exported trace validates
//! against `scripts/check_trace.py`.
//!
//! The telemetry flags, registry, and trace writer are process-global,
//! so every test that touches them serializes on [`TELEMETRY_LOCK`].

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::telemetry::{self, registry};
use std::sync::Mutex;

mod common;
use common::with_session;

static TELEMETRY_LOCK: Mutex<()> = Mutex::new(());

fn quick_cfg(alg: Algorithm) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: alg,
        n_clients: 2,
        rounds: 2,
        local_steps: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 512,
        eval_every: 1,
        ..Default::default()
    }
}

/// Everything deterministic a run produces, as exact bit patterns.
fn run_fingerprint(alg: Algorithm, tag: &str) -> Vec<(u64, u64, u64)> {
    with_session(|s| {
        let mut d = Driver::new(s, quick_cfg(alg)).unwrap();
        let rec = d.run(tag).unwrap();
        rec.rounds
            .iter()
            .map(|r| {
                (
                    r.train_loss.to_bits(),
                    r.eval_metric.to_bits(),
                    r.comm_bytes_cum,
                )
            })
            .collect()
    })
}

#[test]
fn histogram_percentiles_match_hand_computed() {
    let h = registry::Histogram::default();
    // three populated buckets: 50 samples at 1 ([0,2)), 30 at 10
    // ([8,16)), 20 at 100 ([64,128))
    for _ in 0..50 {
        h.observe(1);
    }
    for _ in 0..30 {
        h.observe(10);
    }
    for _ in 0..20 {
        h.observe(100);
    }
    assert_eq!(h.count(), 100);
    assert!((h.mean() - 23.5).abs() < 1e-9, "mean {}", h.mean());
    // p10: target rank 10 of the 50 in [0,2) → 0 + (10/50)·2 = 0.4
    assert!((h.percentile(0.10) - 0.4).abs() < 1e-9);
    // p50: rank 50 exhausts bucket 0 exactly → its upper bound, 2.0
    assert!((h.percentile(0.50) - 2.0).abs() < 1e-9);
    // p90: rank 90; 80 precede bucket [64,128) → 64 + (10/20)·64 = 96
    assert!((h.percentile(0.90) - 96.0).abs() < 1e-9);
    // p99: 64 + (19/20)·64 = 124.8
    assert!((h.percentile(0.99) - 124.8).abs() < 1e-9);
}

/// Counter *values* are workload-determined, not schedule-determined:
/// the same run observes identical `client.*` counts whether the local
/// phases run on 1, 4, or 8 worker threads.
#[test]
fn counters_deterministic_across_worker_counts() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    telemetry::enable_metrics();
    let deltas: Vec<(f64, f64)> = [1usize, 4, 8]
        .iter()
        .map(|&w| {
            let before = registry::snapshot();
            with_session(|s| {
                let mut cfg = quick_cfg(Algorithm::Heron);
                cfg.workers = w;
                let mut d = Driver::new(s, cfg).unwrap();
                d.run(&format!("det-w{w}")).unwrap();
            });
            let after = registry::snapshot();
            let delta = |k: &str| {
                after.get(k).copied().unwrap_or(0.0)
                    - before.get(k).copied().unwrap_or(0.0)
            };
            (delta("client.local_steps"), delta("client.zo.probes"))
        })
        .collect();
    assert!(deltas[0].0 > 0.0, "no local steps recorded: {deltas:?}");
    assert!(deltas[0].1 > 0.0, "no ZO probes recorded: {deltas:?}");
    assert!(
        deltas.iter().all(|d| *d == deltas[0]),
        "counter deltas differ across worker counts: {deltas:?}"
    );
}

/// With metrics on, the registry lands in `RunRecord.summary` under its
/// dotted names.
#[test]
fn metrics_flow_into_run_summary() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    telemetry::enable_metrics();
    let rec = with_session(|s| {
        let mut d = Driver::new(s, quick_cfg(Algorithm::Heron)).unwrap();
        d.run("summary-dump").unwrap()
    });
    for key in ["client.local_steps", "client.zo.probes", "runtime.invocations"]
    {
        assert!(
            rec.summary.contains_key(key),
            "summary lacks registry key {key}; keys: {:?}",
            rec.summary.keys().collect::<Vec<_>>()
        );
    }
}

/// THE telemetry contract: recording spans must not perturb a single
/// bit of any algorithm's trajectory.
#[test]
fn traced_runs_are_bit_identical() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let algs = [
        Algorithm::Heron,
        Algorithm::CseFsl,
        Algorithm::FslSage,
        Algorithm::SflV1,
        Algorithm::SflV2,
    ];
    let reference: Vec<_> = algs
        .iter()
        .map(|&a| run_fingerprint(a, "untraced"))
        .collect();

    let path = std::env::temp_dir()
        .join(format!("heron_bitid_{}.json", std::process::id()));
    let p = path.to_str().unwrap();
    telemetry::trace::install(p, "bitid-test").unwrap();
    let traced: Vec<_> = algs
        .iter()
        .map(|&a| run_fingerprint(a, "traced"))
        .collect();
    telemetry::trace::shutdown().unwrap();

    for (i, a) in algs.iter().enumerate() {
        assert_eq!(
            reference[i],
            traced[i],
            "{}: tracing changed the trajectory",
            a.name()
        );
    }
    // and the trace actually recorded the runs it rode along with
    let text = std::fs::read_to_string(p).unwrap();
    assert!(text.contains("\"local_phase\""), "trace missing local_phase");
    assert!(text.contains("\"round\""), "trace missing round spans");
    let _ = std::fs::remove_file(p);
}

/// The exported file passes the same schema checker CI runs
/// (`scripts/check_trace.py --mode run`). Skips when python3 is absent.
#[test]
fn trace_schema_validates() {
    let _g = TELEMETRY_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let path = std::env::temp_dir()
        .join(format!("heron_schema_{}.json", std::process::id()));
    let p = path.to_str().unwrap();
    telemetry::trace::install(p, "schema-test").unwrap();
    with_session(|s| {
        let mut d = Driver::new(s, quick_cfg(Algorithm::Heron)).unwrap();
        d.run("schema").unwrap();
    });
    telemetry::trace::shutdown().unwrap();

    let mut dir = std::env::current_dir().unwrap();
    loop {
        if dir.join("scripts/check_trace.py").exists() {
            break;
        }
        assert!(dir.pop(), "scripts/check_trace.py not found above cwd");
    }
    let script = dir.join("scripts/check_trace.py");
    match std::process::Command::new("python3")
        .arg(&script)
        .arg(p)
        .args(["--mode", "run"])
        .output()
    {
        Ok(out) => assert!(
            out.status.success(),
            "check_trace.py rejected the trace:\n{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr),
        ),
        Err(_) => {
            eprintln!("python3 not found — skipping trace schema validation")
        }
    }
    let _ = std::fs::remove_file(p);
}
