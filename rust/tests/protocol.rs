//! Protocol-level integration + property tests that need the artifacts but
//! not full training runs: runtime invocation edge cases, failure
//! injection, and cross-entry consistency.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::golden;
use heron_sfl::runtime::tensor::TensorValue;
use heron_sfl::runtime::{Call, Session};
use heron_sfl::util::prop::{self, assert_prop};

mod common;
use common::with_session;

fn entry_inputs(
    session: &Session,
    variant: &str,
    entry: &str,
) -> Vec<TensorValue> {
    let v = session.manifest.variant(variant).unwrap();
    let task = v.task.clone();
    v.entry(entry)
        .unwrap()
        .inputs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            golden::bench_input(session, variant, s, i, &task).unwrap()
        })
        .collect()
}

// ---------------------------------------------------------------------------
// failure injection: malformed invocations must fail loudly, not corrupt
// ---------------------------------------------------------------------------

#[test]
fn wrong_arity_rejected() {
    with_session(|s| {
        let mut inputs = entry_inputs(s, "cnn_c1", "zo_step");
        inputs.pop();
        assert!(s.invoke("cnn_c1", "zo_step", &inputs).is_err());
    })
}

#[test]
fn wrong_shape_rejected() {
    with_session(|s| {
        let mut inputs = entry_inputs(s, "cnn_c1", "zo_step");
        inputs[0] = TensorValue::F32(vec![0.0; 7]); // wrong theta length
        assert!(s.invoke("cnn_c1", "zo_step", &inputs).is_err());
    })
}

#[test]
fn wrong_dtype_rejected() {
    with_session(|s| {
        let mut inputs = entry_inputs(s, "cnn_c1", "zo_step");
        let n = inputs[0].len();
        inputs[0] = TensorValue::I32(vec![0; n]);
        assert!(s.invoke("cnn_c1", "zo_step", &inputs).is_err());
    })
}

#[test]
fn unknown_variant_and_entry_rejected() {
    with_session(|s| {
        assert!(s.invoke("no_such_variant", "zo_step", &[]).is_err());
        assert!(s.invoke("cnn_c1", "no_such_entry", &[]).is_err());
    })
}

#[test]
fn call_builder_catches_missing_and_unknown_args() {
    with_session(|s| {
        let err = Call::new(s, "cnn_c1", "local_loss")
            .arg("theta_l", vec![0.0f32; 5306])
            .run();
        assert!(err.is_err(), "missing x/y should fail");
        let inputs = entry_inputs(s, "cnn_c1", "local_loss");
        let err = Call::new(s, "cnn_c1", "local_loss")
            .arg("theta_l", inputs[0].clone())
            .arg("x", inputs[1].clone())
            .arg("y", inputs[2].clone())
            .arg("bogus", 1.0f32)
            .run();
        assert!(err.is_err(), "unknown arg should fail");
    })
}

#[test]
fn session_survives_failed_invocations() {
    with_session(|s| {
        // inject a failure, then confirm a good call still works
        let mut bad = entry_inputs(s, "cnn_c1", "local_loss");
        bad[0] = TensorValue::F32(vec![0.0; 3]);
        let _ = s.invoke("cnn_c1", "local_loss", &bad);
        let good = entry_inputs(s, "cnn_c1", "local_loss");
        let outs = s.invoke("cnn_c1", "local_loss", &good).unwrap();
        assert!(outs[0].scalar_f32().unwrap().is_finite());
    })
}

// ---------------------------------------------------------------------------
// cross-entry consistency
// ---------------------------------------------------------------------------

#[test]
fn zo_step_determinism_through_pjrt() {
    with_session(|s| {
        let inputs = entry_inputs(s, "cnn_c1", "zo_step");
        let a = s.invoke("cnn_c1", "zo_step", &inputs).unwrap();
        let b = s.invoke("cnn_c1", "zo_step", &inputs).unwrap();
        assert_eq!(
            a[0].as_f32().unwrap(),
            b[0].as_f32().unwrap(),
            "same seed must give identical updates"
        );
    })
}

#[test]
fn zo_seed_sensitivity_through_pjrt() {
    with_session(|s| {
        let v = s.manifest.variant("cnn_c1").unwrap();
        let espec = v.entry("zo_step").unwrap();
        let seed_idx = espec
            .inputs
            .iter()
            .position(|t| t.name == "seed")
            .unwrap();
        let mut inputs = entry_inputs(s, "cnn_c1", "zo_step");
        let a = s.invoke("cnn_c1", "zo_step", &inputs).unwrap();
        inputs[seed_idx] = TensorValue::ScalarI32(0x1234);
        let b = s.invoke("cnn_c1", "zo_step", &inputs).unwrap();
        assert_ne!(a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
    })
}

#[test]
fn zo_probe_count_property() {
    // more probes should (weakly) reduce estimator variance: measure the
    // spread of the update norm across seeds for n_pert=1 vs 4
    with_session(|sess| {
        let v = sess.manifest.variant("cnn_c1").unwrap();
        let espec = v.entry("zo_step").unwrap();
        let pos = |name: &str| {
            espec.inputs.iter().position(|t| t.name == name).unwrap()
        };
        let base_inputs = entry_inputs(sess, "cnn_c1", "zo_step");
        let theta0 = base_inputs[0].as_f32().unwrap().to_vec();
        let spread = |np: i32| {
            let mut deltas = Vec::new();
            for s in 0..6 {
                let mut inputs = base_inputs.clone();
                inputs[pos("seed")] = TensorValue::ScalarI32(100 + s);
                inputs[pos("n_pert")] = TensorValue::ScalarI32(np);
                let out =
                    sess.invoke("cnn_c1", "zo_step", &inputs).unwrap();
                let th = out[0].as_f32().unwrap();
                let d: f64 = th
                    .iter()
                    .zip(&theta0)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                deltas.push(d);
            }
            let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
            let var = deltas
                .iter()
                .map(|d| (d - mean) * (d - mean))
                .sum::<f64>()
                / deltas.len() as f64;
            var.sqrt() / mean
        };
        // coefficient of variation should not grow with probes
        assert!(spread(4) < spread(1) * 1.5);
    })
}

#[test]
fn eval_accuracy_bounded_property() {
    with_session(|sess| {
        prop::check(5, |g| {
            let scale = g.f32_in(0.1..2.0);
            let mut inputs = entry_inputs(sess, "cnn_c1", "eval_full");
            // random rescale of theta keeps accuracy within [0, 1]
            if let TensorValue::F32(t) = &mut inputs[0] {
                for x in t.iter_mut() {
                    *x *= scale;
                }
            }
            let outs =
                sess.invoke("cnn_c1", "eval_full", &inputs).unwrap();
            let s1 = outs[0].scalar_f32().unwrap();
            let s2 = outs[1].scalar_f32().unwrap();
            assert_prop!(
                s1 >= 0.0 && s1 <= s2,
                "correct count {s1} outside [0, {s2}] (scale {scale})"
            );
            Ok(())
        });
    })
}

#[test]
fn heron_required_entries_exist_for_all_variants() {
    // every trainable variant supports at least HERON itself (the *_pallas
    // variants are kernel-path golden checks, not trainable configurations)
    with_session(|s| {
        for (name, v) in &s.manifest.variants {
            if name.ends_with("_pallas") {
                continue;
            }
            for e in Algorithm::Heron.required_entries() {
                assert!(
                    v.entries.contains_key(*e),
                    "{name} missing {e} (HERON must run everywhere)"
                );
            }
        }
    })
}

#[test]
fn runtime_stats_accumulate() {
    with_session(|s| {
        let before = s.stats().invocations;
        let inputs = entry_inputs(s, "cnn_c1", "local_loss");
        s.invoke("cnn_c1", "local_loss", &inputs).unwrap();
        let after = s.stats();
        assert!(after.invocations > before);
        assert!(after.bytes_in > 0 && after.exec_seconds > 0.0);
    })
}
