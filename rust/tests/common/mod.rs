//! Shared test plumbing: one process-wide Session.
//!
//! `Session` is `Sync` (immutable manifest + native engine, stats behind a
//! mutex), so the test binary's threads can share a single lazily-built
//! instance directly. The first access also triggers artifact generation
//! when the `artifacts/` tree is missing (see `runtime::artifacts`).

use heron_sfl::runtime::Session;
use std::sync::OnceLock;

static SESSION: OnceLock<Session> = OnceLock::new();

/// Run `f` against the shared session.
pub fn with_session<R>(f: impl FnOnce(&Session) -> R) -> R {
    f(SESSION.get_or_init(|| {
        Session::open_default().expect("opening default session")
    }))
}
