//! Shared test plumbing: a process-wide Session behind a mutex.
//!
//! The xla crate's handles are `Rc`-based (single-threaded by design — see
//! DESIGN.md §7), but `cargo test` runs tests on multiple threads. All test
//! access is serialized through one mutex, which makes the wrapper sound in
//! practice: no `Rc` clone or PJRT call ever happens concurrently.

use heron_sfl::runtime::Session;
use once_cell::sync::Lazy;
use std::sync::Mutex;

struct SendSession(Session);
// SAFETY: every use is behind SESSION's mutex; the inner Rc/RefCell state is
// never touched from two threads at once.
unsafe impl Send for SendSession {}

static SESSION: Lazy<Mutex<SendSession>> = Lazy::new(|| {
    Mutex::new(SendSession(
        Session::open_default()
            .expect("run `make artifacts` before cargo test"),
    ))
});

/// Run `f` with exclusive access to the shared session.
pub fn with_session<R>(f: impl FnOnce(&Session) -> R) -> R {
    let guard = SESSION.lock().unwrap_or_else(|p| p.into_inner());
    f(&guard.0)
}
