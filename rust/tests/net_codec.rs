//! Property tests for the payload codec subsystem (`net::codec`,
//! `util::prop` substrate): roundtrip every codec over adversarial
//! payload shapes (empty, single-element, lengths that don't divide the
//! packing chunk), bitwise identity for the `f32` leg, quantization
//! error bounds for the affine legs, and *rejection — never a panic or
//! an unbounded allocation* on truncated, corrupted, bad-scale, and
//! oversized-header envelopes.

use heron_sfl::net::codec::{
    self, Codec, CodecError, GradCodec, MAX_ELEMS, TAG_F32, TAG_INT4,
    TAG_INT8, TAG_TOPK,
};
use heron_sfl::util::prop::{self, Gen};

fn arb_payload(g: &mut Gen, max: usize) -> Vec<f32> {
    g.vec_f32(0..max, -1e6..1e6)
}

fn arb_codec(g: &mut Gen) -> Codec {
    [Codec::F32, Codec::Int8, Codec::Int4][g.usize_in(0..3)]
}

/// Awkward payload lengths every codec must survive: empty, one element,
/// and counts that don't divide the int4 pair or a round chunk.
const SHAPES: [usize; 7] = [0, 1, 2, 3, 5, 17, 257];

#[test]
fn f32_codec_is_bitwise_identity() {
    prop::check(300, |g| {
        let data = arb_payload(g, 512);
        let enc = codec::encode(Codec::F32, &data);
        prop::assert_prop!(
            enc.len() == codec::encoded_len(Codec::F32, data.len()),
            "envelope size formula"
        );
        let back = codec::decode(&enc).map_err(|e| format!("{e}"))?;
        prop::assert_prop!(back.len() == data.len(), "length");
        for (a, b) in data.iter().zip(&back) {
            prop::assert_prop!(
                a.to_bits() == b.to_bits(),
                "f32 leg must be bit-identical"
            );
        }
        Ok(())
    });
    // non-finite bit patterns survive the identity leg exactly
    for bits in [0x7FC0_0001u32, 0x7F80_0000, 0xFF80_0000, 0x0000_0001] {
        let data = vec![f32::from_bits(bits), -0.0];
        let back = codec::decode(&codec::encode_f32(&data)).unwrap();
        assert_eq!(back[0].to_bits(), bits);
        assert_eq!(back[1].to_bits(), (-0.0f32).to_bits());
    }
}

#[test]
fn affine_codecs_bound_max_abs_error_by_half_scale() {
    prop::check(300, |g| {
        let data = arb_payload(g, 512);
        let (lo, hi) = data.iter().fold(
            (f32::INFINITY, f32::NEG_INFINITY),
            |(lo, hi), &v| (lo.min(v), hi.max(v)),
        );
        let range = if hi > lo { hi - lo } else { 0.0 };
        for (c, qmax) in [(Codec::Int8, 255.0f32), (Codec::Int4, 15.0)] {
            let enc = codec::encode(c, &data);
            prop::assert_prop!(
                enc.len() == codec::encoded_len(c, data.len()),
                "{}: envelope size formula",
                c.name()
            );
            let back = codec::decode(&enc).map_err(|e| format!("{e}"))?;
            prop::assert_prop!(back.len() == data.len(), "length");
            // round-to-nearest over a [lo, hi] grid of qmax+1 levels:
            // within half a quantization step, plus f32 rounding slop
            // relative to the range AND to the zero-point magnitude —
            // dequantizing zp + q·scale rounds at ulp(|zp|), which
            // dominates when a payload clusters tightly far from zero
            let max_abs = lo.abs().max(hi.abs());
            let tol =
                (range / qmax) * 0.5 + (range + max_abs) * 1e-5 + 1e-6;
            for (a, b) in data.iter().zip(&back) {
                prop::assert_prop!(
                    (a - b).abs() <= tol,
                    "{}: |{a} - {b}| > {tol}",
                    c.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn every_codec_roundtrips_awkward_shapes() {
    for n in SHAPES {
        let data: Vec<f32> =
            (0..n).map(|i| (i as f32 - 2.5) * 0.75).collect();
        for c in [Codec::F32, Codec::Int8, Codec::Int4] {
            let enc = codec::encode(c, &data);
            assert_eq!(enc.len(), codec::encoded_len(c, n), "{}", c.name());
            let back = codec::decode(&enc)
                .unwrap_or_else(|e| panic!("{} n={n}: {e}", c.name()));
            assert_eq!(back.len(), n, "{} n={n}", c.name());
        }
        for ratio in [0.01f32, 0.25, 1.0] {
            let gc = GradCodec::TopK(ratio);
            let enc = codec::encode_grad(gc, &data);
            assert_eq!(enc.len(), codec::encoded_len_grad(gc, n));
            assert_eq!(codec::decode(&enc).unwrap().len(), n);
        }
    }
    // constant payloads quantize to scale 0 and decode exactly
    let flat = vec![0.375f32; 33];
    for c in [Codec::Int8, Codec::Int4] {
        let back = codec::decode(&codec::encode(c, &flat)).unwrap();
        assert!(back.iter().all(|&v| v == 0.375), "{}", c.name());
    }
}

#[test]
fn topk_keeps_largest_magnitudes_bitwise_and_zeroes_the_rest() {
    prop::check(300, |g| {
        let data = arb_payload(g, 256);
        let ratio = g.f32_in(0.01..1.0);
        let k = codec::topk_k(data.len(), ratio);
        let enc = codec::encode_grad(GradCodec::TopK(ratio), &data);
        prop::assert_prop!(
            enc.len() == codec::encoded_len_grad(
                GradCodec::TopK(ratio),
                data.len(),
            ),
            "envelope size formula"
        );
        let back = codec::decode(&enc).map_err(|e| format!("{e}"))?;
        prop::assert_prop!(back.len() == data.len(), "length");
        let kept = back.iter().filter(|v| **v != 0.0).count();
        prop::assert_prop!(kept <= k, "kept {kept} > k {k}");
        let mut dropped_max = 0.0f32;
        let mut kept_min = f32::INFINITY;
        for (a, b) in data.iter().zip(&back) {
            if *b != 0.0 || (*a == 0.0 && k == data.len()) {
                // surviving elements ship their exact bit pattern
                prop::assert_prop!(
                    a.to_bits() == b.to_bits(),
                    "kept value must be bitwise-preserved"
                );
                kept_min = kept_min.min(a.abs());
            } else {
                dropped_max = dropped_max.max(a.abs());
            }
        }
        // zeroed original values can make `kept` undercount, so only
        // enforce the selection order when the partition is visible
        if kept == k && k < data.len() {
            prop::assert_prop!(
                dropped_max <= kept_min,
                "dropped |{dropped_max}| outranks kept |{kept_min}|"
            );
        }
        Ok(())
    });
    // deterministic spot check: k=2 of 4 keeps the two largest |v|
    let enc = codec::encode_topk(&[3.0, -5.0, 1.0, 4.0], 0.5);
    assert_eq!(codec::decode(&enc).unwrap(), vec![0.0, -5.0, 0.0, 4.0]);
    // ratio 1.0 is a full bitwise roundtrip
    let full = [f32::NAN, 0.0, -2.0];
    let back =
        codec::decode(&codec::encode_topk(&full, 1.0)).unwrap();
    for (a, b) in full.iter().zip(&back) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn truncation_always_rejected_never_panics() {
    prop::check(300, |g| {
        let data = arb_payload(g, 128);
        let enc = match g.usize_in(0..4) {
            0 => codec::encode(Codec::F32, &data),
            1 => codec::encode(Codec::Int8, &data),
            2 => codec::encode(Codec::Int4, &data),
            _ => codec::encode_topk(&data, g.f32_in(0.01..1.0)),
        };
        let cut = g.usize_in(0..enc.len());
        match codec::decode(&enc[..cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("cut {cut}/{} decoded", enc.len())),
        }
    });
}

#[test]
fn corruption_and_garbage_never_panic_or_overallocate() {
    prop::check(500, |g| {
        // single-byte corruption of a valid envelope: there is no CRC at
        // this layer (the wire frame carries it), so decode may succeed —
        // it must simply never panic, and any Ok stays header-bounded
        let data = arb_payload(g, 64);
        let mut enc = codec::encode(arb_codec(g), &data);
        let pos = g.usize_in(0..enc.len());
        enc[pos] ^= (g.usize_in(1..256)) as u8;
        if let Ok(out) = codec::decode(&enc) {
            prop::assert_prop!(
                out.len() <= MAX_ELEMS as usize,
                "decoded past the element cap"
            );
        }
        // pure garbage
        let n = g.usize_in(0..64);
        let junk: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
        let _ = codec::decode(&junk);
        Ok(())
    });
}

#[test]
fn bad_scale_headers_are_typed_errors() {
    for bits in [f32::NAN.to_bits(), f32::INFINITY.to_bits()] {
        for tag in [TAG_INT8, TAG_INT4] {
            let mut enc = if tag == TAG_INT8 {
                codec::encode_int8(&[1.0, 2.0])
            } else {
                codec::encode_int4(&[1.0, 2.0])
            };
            enc[5..9].copy_from_slice(&bits.to_le_bytes()); // scale
            assert_eq!(codec::decode(&enc), Err(CodecError::BadScale));
            let mut enc2 = codec::encode_int8(&[1.0, 2.0]);
            enc2[9..13].copy_from_slice(&bits.to_le_bytes()); // zero point
            assert_eq!(codec::decode(&enc2), Err(CodecError::BadScale));
        }
    }
}

#[test]
fn hostile_headers_reject_before_allocating() {
    // element count above the cap: typed error, no 16 GiB Vec
    for tag in [TAG_F32, TAG_INT8, TAG_INT4, TAG_TOPK] {
        let mut enc = vec![tag];
        enc.extend_from_slice(&(MAX_ELEMS + 1).to_le_bytes());
        enc.extend_from_slice(&[0u8; 16]);
        assert_eq!(
            codec::decode(&enc),
            Err(CodecError::TooLarge(MAX_ELEMS + 1)),
            "tag {tag}"
        );
    }
    // an in-cap count with a tiny body is truncation, not an allocation
    let mut enc = vec![TAG_F32];
    enc.extend_from_slice(&MAX_ELEMS.to_le_bytes());
    enc.push(0);
    assert_eq!(codec::decode(&enc), Err(CodecError::Truncated));
    // unknown tag
    let mut enc = vec![9u8];
    enc.extend_from_slice(&1u32.to_le_bytes());
    enc.extend_from_slice(&1.0f32.to_le_bytes());
    assert_eq!(codec::decode(&enc), Err(CodecError::BadTag(9)));
    // trailing bytes after a complete payload are malformed
    let mut enc = codec::encode_f32(&[1.0, 2.0]);
    enc.push(0);
    assert!(matches!(
        codec::decode(&enc),
        Err(CodecError::Malformed(_))
    ));
    // top-k: k > n, and an index past the payload end
    let mut enc = vec![TAG_TOPK];
    enc.extend_from_slice(&2u32.to_le_bytes()); // n = 2
    enc.extend_from_slice(&3u32.to_le_bytes()); // k = 3 (!)
    assert!(matches!(
        codec::decode(&enc),
        Err(CodecError::Malformed(_))
    ));
    let mut enc = vec![TAG_TOPK];
    enc.extend_from_slice(&2u32.to_le_bytes()); // n = 2
    enc.extend_from_slice(&1u32.to_le_bytes()); // k = 1
    enc.extend_from_slice(&7u32.to_le_bytes()); // idx = 7 (!)
    enc.extend_from_slice(&1.0f32.to_le_bytes());
    assert_eq!(
        codec::decode(&enc),
        Err(CodecError::BadIndex { idx: 7, n: 2 })
    );
}

#[test]
fn decode_expect_enforces_the_negotiated_tag() {
    let enc = codec::encode_f32(&[1.0]);
    assert_eq!(
        codec::decode_expect(&enc, TAG_INT8),
        Err(CodecError::WrongCodec { got: TAG_F32, want: TAG_INT8 })
    );
    assert!(codec::decode_expect(&enc, TAG_F32).is_ok());
    assert_eq!(
        codec::decode_expect(&[], TAG_F32),
        Err(CodecError::Truncated)
    );
}

#[test]
fn transcode_matches_its_own_wire_decode() {
    // the encode-once rule: the in-process driver's transcoded values
    // must equal what a networked dispatcher decodes from the envelope
    prop::check(200, |g| {
        let data = arb_payload(g, 256);
        for c in [Codec::F32, Codec::Int8, Codec::Int4] {
            let mut local = data.clone();
            let enc = codec::transcode(c, &mut local);
            let wire = codec::decode(&enc).map_err(|e| format!("{e}"))?;
            for (a, b) in local.iter().zip(&wire) {
                prop::assert_prop!(
                    a.to_bits() == b.to_bits(),
                    "{}: transcode != wire decode",
                    c.name()
                );
            }
        }
        Ok(())
    });
}
