//! Chaos gate: the fault-tolerance acceptance tests for the networked
//! dispatcher (`net::server` v5).
//!
//! * **kill a client mid-round** — a connection that vanishes after the
//!   round broadcast (simulated `kill -9`: the transport is dropped with
//!   no protocol goodbye) is cut from the open round, typed-counted
//!   (`net_disconnects`), and the run finishes every remaining round
//!   with the surviving cohort.
//! * **mute straggler + wall deadline** — `--round_deadline_ms` on the
//!   wire path: a client that handshakes but never uploads is cut at
//!   the wall-clock deadline every round; the run never wedges and the
//!   cut roster lands in `clients_cut`.
//! * **kill-and-restore the server** — `halt_after` (the in-process
//!   stand-in for `kill -9`, exercised for real by
//!   `scripts/chaos_smoke.sh`) aborts the run right after a checkpoint;
//!   a fresh server restoring from that checkpoint with fresh clients
//!   finishes **bit-identically** to an uninterrupted reference run.
//! * **signal shutdown** — a pending SIGINT/SIGTERM (raised via the
//!   test hook `signal::request`) turns into a final checkpoint plus a
//!   clean `Shutdown` broadcast; clients exit zero, the checkpoint
//!   loads.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::checkpoint;
use heron_sfl::coordinator::config::{RunConfig, ZoWireMode};
use heron_sfl::net::transport::{loopback_pair, Transport};
use heron_sfl::net::wire::VERSION;
use heron_sfl::net::{
    run_client, serve_transports, serve_transports_opts, ClientReport, Msg,
    NetReport, ServeOptions,
};
use heron_sfl::runtime::Session;
use heron_sfl::util::signal;

mod common;
use common::with_session;

fn chaos_cfg(rounds: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 4,
        rounds,
        local_steps: 4,
        upload_every: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        workers: 1,
        ..Default::default()
    }
}

/// serve + `n_conns` well-behaved `run_client`s over loopback, with
/// fault-tolerance options on the server side. Returns the server's
/// result and every client's report (clients must always exit cleanly —
/// even when the server aborts, its epilogue broadcasts `Shutdown`).
fn net_serve(
    session: &Session,
    cfg: &RunConfig,
    n_conns: usize,
    opts: ServeOptions,
) -> (anyhow::Result<NetReport>, Vec<ClientReport>) {
    let mut server_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut client_ends = Vec::new();
    for _ in 0..n_conns {
        let (s, c) = loopback_pair();
        server_ends.push(Box::new(s));
        client_ends.push(c);
    }
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_transports_opts(session, cfg.clone(), server_ends, "chaos", &opts)
        });
        let clients: Vec<_> = client_ends
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                scope.spawn(move || {
                    run_client(session, Box::new(c), &format!("edge-{i}"))
                })
            })
            .collect();
        let res = server.join().expect("server panicked");
        let reports = clients
            .into_iter()
            .map(|h| h.join().expect("client panicked").expect("client"))
            .collect();
        (res, reports)
    })
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("heron_chaos_{tag}_{}.ckpt", std::process::id()))
}

/// A connection that dies mid-round with no goodbye: the server must cut
/// its clients from the open round, keep the survivors' round intact,
/// finish every remaining round, and report the churn in typed summary
/// keys — never abort the run.
#[test]
fn client_killed_mid_round_is_cut_and_the_run_completes() {
    with_session(|s| {
        let cfg = chaos_cfg(3);
        let (srv0, cli0) = loopback_pair();
        let (srv1, cli1) = loopback_pair();
        let ends: Vec<Box<dyn Transport>> =
            vec![Box::new(srv0), Box::new(srv1)];
        let (report, good) = std::thread::scope(|scope| {
            let server = scope.spawn(|| {
                serve_transports(s, cfg.clone(), ends, "chaos-kill")
            });
            let good = scope
                .spawn(|| run_client(s, Box::new(cli0), "survivor"));
            let flaky = scope.spawn(move || {
                // handshake like a real client, then vanish right after
                // the first round's model broadcast — a kill -9, not a
                // protocol goodbye
                let mut t: Box<dyn Transport> = Box::new(cli1);
                t.send(&Msg::Hello {
                    name: "flaky".into(),
                    protocol: VERSION as u32,
                    lanes: 1,
                    codecs: heron_sfl::net::codec::SUPPORTED.to_vec(),
                })
                .expect("hello");
                loop {
                    match t.recv().expect("recv") {
                        Some(Msg::ModelSync { .. }) | None => break,
                        Some(_) => continue,
                    }
                }
                // drop(t): the socket just disappears
            });
            let report = server
                .join()
                .expect("server panicked")
                .expect("server must survive a killed client");
            flaky.join().expect("flaky client panicked");
            let good = good
                .join()
                .expect("client panicked")
                .expect("surviving client");
            (report, good)
        });

        assert_eq!(
            report.record.rounds.len(),
            cfg.rounds,
            "every round must finalize despite the kill"
        );
        assert!(report.disconnects >= 1, "the kill is typed and counted");
        // conn 1 owned clients 1 and 3: cut in the open round, and cut
        // up front in every later round
        assert_eq!(report.clients_cut, (2 * cfg.rounds) as u64);
        assert!(report.record.summary["net_disconnects"] >= 1.0);
        assert_eq!(
            report.record.summary["clients_cut"],
            (2 * cfg.rounds) as f64
        );
        for r in &report.record.rounds {
            assert!(r.train_loss.is_finite());
        }
        // the survivor saw the whole run and a clean shutdown
        assert_eq!(good.rounds, cfg.rounds);
        assert_eq!(good.shutdown_reason, "run complete");
    });
}

/// A mute straggler under a wall-clock round deadline: it handshakes and
/// listens but never uploads. Without the deadline the round would wait
/// forever; with it, the server finalizes each round with the uploads it
/// has and cuts the mute clients — every round, without wedging.
#[test]
fn mute_straggler_is_cut_at_the_wall_deadline_every_round() {
    with_session(|s| {
        let mut cfg = chaos_cfg(2);
        cfg.round_deadline_ms = 1500; // generous for the loopback survivor
        cfg.validate().unwrap();
        let (srv0, cli0) = loopback_pair();
        let (srv1, cli1) = loopback_pair();
        let ends: Vec<Box<dyn Transport>> =
            vec![Box::new(srv0), Box::new(srv1)];
        let (report, good) = std::thread::scope(|scope| {
            let server = scope.spawn(|| {
                serve_transports(s, cfg.clone(), ends, "chaos-deadline")
            });
            let good =
                scope.spawn(|| run_client(s, Box::new(cli0), "prompt"));
            let mute = scope.spawn(move || {
                let mut t: Box<dyn Transport> = Box::new(cli1);
                t.send(&Msg::Hello {
                    name: "mute".into(),
                    protocol: VERSION as u32,
                    lanes: 1,
                    codecs: heron_sfl::net::codec::SUPPORTED.to_vec(),
                })
                .expect("hello");
                // listen politely, upload nothing, leave on Shutdown
                loop {
                    match t.recv().expect("recv") {
                        Some(Msg::Shutdown { .. }) | None => break,
                        Some(_) => continue,
                    }
                }
            });
            let report = server
                .join()
                .expect("server panicked")
                .expect("server must cut the mute straggler, not hang");
            mute.join().expect("mute client panicked");
            let good = good
                .join()
                .expect("client panicked")
                .expect("prompt client");
            (report, good)
        });

        assert_eq!(report.record.rounds.len(), cfg.rounds);
        assert_eq!(
            report.clients_cut,
            (2 * cfg.rounds) as u64,
            "clients 1 and 3 cut at the deadline every round"
        );
        assert_eq!(report.disconnects, 0, "the mute peer never disconnected");
        assert_eq!(good.rounds, cfg.rounds);
    });
}

/// The restore contract: kill the server right after a checkpoint
/// (`halt_after`, the in-process `kill -9`), bring up a fresh server
/// from that checkpoint with fresh clients, and the finished run is
/// **bit-identical** to a never-interrupted reference — per-round train
/// losses, eval metrics, analytic comm bytes, and both final models.
#[test]
fn killed_and_restored_server_finishes_bit_identical() {
    with_session(|s| {
        let cfg = chaos_cfg(4);
        let ckpt = ckpt_path("restore");
        let _ = std::fs::remove_file(&ckpt);

        // leg A: the uninterrupted reference
        let (a, _) = net_serve(s, &cfg, 2, ServeOptions::default());
        let a = a.expect("reference run");

        // leg B1: checkpoint every 2 rounds, crash right after round 2
        let (b1, b1_clients) = net_serve(s, &cfg, 2, ServeOptions {
            checkpoint_every: 2,
            checkpoint_path: Some(ckpt.clone()),
            halt_after: 2,
            ..Default::default()
        });
        let err = b1.err().expect("halt_after must abort the run");
        assert!(
            format!("{err:#}").contains("halted"),
            "unexpected abort: {err:#}"
        );
        assert!(ckpt.exists(), "the crash happened after the checkpoint");
        // even an aborted server says goodbye: clients exit clean
        for c in &b1_clients {
            assert_eq!(c.rounds, 2);
        }

        // leg B2: fresh server + fresh clients, restored from the
        // checkpoint — the clients fast-forward their data streams from
        // the Assign's phase counts
        let (b2, _) = net_serve(s, &cfg, 2, ServeOptions {
            restore: Some(ckpt.clone()),
            ..Default::default()
        });
        let b2 = b2.expect("restored run");

        assert_eq!(b2.record.rounds.len(), cfg.rounds);
        assert_eq!(a.final_theta_l, b2.final_theta_l, "θ_l");
        assert_eq!(a.final_theta_s, b2.final_theta_s, "θ_s");
        for (x, y) in a.record.rounds.iter().zip(&b2.record.rounds) {
            assert_eq!(x.round, y.round);
            assert_eq!(
                x.train_loss.to_bits(),
                y.train_loss.to_bits(),
                "round {} train loss",
                x.round
            );
            assert_eq!(
                x.eval_metric.to_bits(),
                y.eval_metric.to_bits(),
                "round {} eval metric",
                x.round
            );
            assert_eq!(x.comm_bytes_cum, y.comm_bytes_cum);
        }
        let _ = std::fs::remove_file(&ckpt);
    });
}

/// The restore contract under the lean downlink (`--zo_wire seed_agg`):
/// the checkpoint carries no seed-space roster (it is round-transient),
/// so a restored server re-bootstraps every fresh client with one dense
/// broadcast and goes lean again from the following round — and still
/// finishes **bit-identically** to the uninterrupted seed_agg
/// reference, analytic accounting included (the round-indexed CostBook
/// sync formula does not restart at the restore boundary). The final
/// model also matches the dense-sync (theta-wire) reference, pinning
/// the whole seed-space pipeline through the crash.
#[test]
fn seed_agg_killed_and_restored_finishes_bit_identical() {
    with_session(|s| {
        let mut cfg = chaos_cfg(4);
        cfg.zo_wire = ZoWireMode::SeedAgg;
        cfg.validate().unwrap();
        let ckpt = ckpt_path("seed_agg_restore");
        let _ = std::fs::remove_file(&ckpt);

        // dense-sync reference: the identical run under the theta wire
        let mut dense = cfg.clone();
        dense.zo_wire = ZoWireMode::Theta;
        let (d, _) = net_serve(s, &dense, 2, ServeOptions::default());
        let d = d.expect("dense-sync reference run");

        // leg A: the uninterrupted seed_agg reference
        let (a, _) = net_serve(s, &cfg, 2, ServeOptions::default());
        let a = a.expect("seed_agg reference run");
        assert_eq!(
            a.final_theta_l, d.final_theta_l,
            "seed_agg θ_l diverged from the dense-sync reference"
        );

        // leg B1: checkpoint every 2 rounds, crash right after round 2 —
        // rounds 0..2 already ran lean (bootstrap + SeedSync) pre-crash
        let (b1, b1_clients) = net_serve(s, &cfg, 2, ServeOptions {
            checkpoint_every: 2,
            checkpoint_path: Some(ckpt.clone()),
            halt_after: 2,
            ..Default::default()
        });
        let err = b1.err().expect("halt_after must abort the run");
        assert!(
            format!("{err:#}").contains("halted"),
            "unexpected abort: {err:#}"
        );
        assert!(ckpt.exists(), "the crash happened after the checkpoint");
        for c in &b1_clients {
            assert_eq!(c.rounds, 2);
        }

        // leg B2: restored server + fresh clients. No client holds a
        // cached θ, so round 2 must fall back to the dense bootstrap
        // broadcast, then round 3 goes lean again — and the whole run
        // matches the uninterrupted reference bit for bit.
        let (b2, _) = net_serve(s, &cfg, 2, ServeOptions {
            restore: Some(ckpt.clone()),
            ..Default::default()
        });
        let b2 = b2.expect("restored seed_agg run");

        assert_eq!(b2.record.rounds.len(), cfg.rounds);
        assert_eq!(a.final_theta_l, b2.final_theta_l, "θ_l");
        assert_eq!(a.final_theta_s, b2.final_theta_s, "θ_s");
        for (x, y) in a.record.rounds.iter().zip(&b2.record.rounds) {
            assert_eq!(x.round, y.round);
            assert_eq!(
                x.train_loss.to_bits(),
                y.train_loss.to_bits(),
                "round {} train loss",
                x.round
            );
            assert_eq!(
                x.eval_metric.to_bits(),
                y.eval_metric.to_bits(),
                "round {} eval metric",
                x.round
            );
            assert_eq!(x.comm_bytes_cum, y.comm_bytes_cum);
        }
        let _ = std::fs::remove_file(&ckpt);
    });
}

/// Rejoin under `--zo_wire seed_agg`, over real TCP (the rejoin
/// acceptor is TCP-only): a connection dies mid-run with no goodbye, a
/// replacement connects, adopts the dead lane block, and — the lean
/// downlink's churn contract — gets a dense θ bootstrap on its first
/// broadcast (never a SeedSync it has no cached θ to replay), then lean
/// SeedSync rounds after that, while the survivor keeps receiving lean
/// broadcasts in the same rounds. The run must finish every round and
/// both clients must exit clean.
#[test]
fn seed_agg_rejoiner_bootstraps_dense_and_run_completes() {
    with_session(|s| {
        let mut cfg = chaos_cfg(6);
        cfg.zo_wire = ZoWireMode::SeedAgg;
        cfg.validate().unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (report, survivor, rejoiner) = std::thread::scope(|scope| {
            let server = {
                let cfg = cfg.clone();
                scope.spawn(move || {
                    heron_sfl::net::serve_tcp_opts(
                        s,
                        cfg,
                        listener,
                        2,
                        "chaos-seed-agg-rejoin",
                        ServeOptions { rejoin: true, ..Default::default() },
                    )
                })
            };
            let survivor = {
                let addr = addr.clone();
                scope.spawn(move || {
                    let t = heron_sfl::net::TcpTransport::connect(&addr)
                        .expect("survivor connect");
                    run_client(s, Box::new(t), "survivor")
                })
            };
            // the flaky peer: handshake, then vanish right after the
            // first round's broadcast — kill -9, no protocol goodbye
            {
                let mut t: Box<dyn Transport> = Box::new(
                    heron_sfl::net::TcpTransport::connect(&addr)
                        .expect("flaky connect"),
                );
                t.send(&Msg::Hello {
                    name: "flaky".into(),
                    protocol: VERSION as u32,
                    lanes: 1,
                    codecs: heron_sfl::net::codec::SUPPORTED.to_vec(),
                })
                .expect("hello");
                loop {
                    match t.recv().expect("recv") {
                        Some(Msg::ModelSync { .. }) | None => break,
                        Some(_) => continue,
                    }
                }
            }
            // only now — with the dead conn's lane block free — bring up
            // the replacement; the acceptor parks it and the dispatcher
            // adopts it at a round boundary with a dense re-bootstrap
            let rejoiner = {
                let addr = addr.clone();
                scope.spawn(move || {
                    let t = heron_sfl::net::TcpTransport::connect(&addr)
                        .expect("rejoiner connect");
                    run_client(s, Box::new(t), "replacement")
                })
            };
            let report = server
                .join()
                .expect("server panicked")
                .expect("server must survive churn + rejoin");
            let survivor = survivor
                .join()
                .expect("survivor panicked")
                .expect("survivor");
            let rejoiner = rejoiner
                .join()
                .expect("rejoiner panicked")
                .expect("rejoiner");
            (report, survivor, rejoiner)
        });

        assert_eq!(
            report.record.rounds.len(),
            cfg.rounds,
            "every round must finalize despite churn"
        );
        assert!(report.disconnects >= 1, "the kill is typed and counted");
        assert_eq!(survivor.rounds, cfg.rounds);
        assert_eq!(survivor.shutdown_reason, "run complete");
        // the replacement adopted the dead lane block and ran lean
        // rounds from its dense bootstrap — a SeedSync it could not
        // replay would have errored its process instead of completing
        assert!(
            rejoiner.phases > 0,
            "replacement was never adopted into the run"
        );
        assert_eq!(rejoiner.shutdown_reason, "run complete");
        for r in &report.record.rounds {
            assert!(r.train_loss.is_finite());
        }
    });
}

/// A restore under the wrong config must refuse loudly — continuing a
/// checkpoint into a different experiment would silently corrupt it.
#[test]
fn restore_refuses_a_config_mismatch() {
    with_session(|s| {
        let cfg = chaos_cfg(2);
        let ckpt = ckpt_path("mismatch");
        let _ = std::fs::remove_file(&ckpt);
        let (r, _) = net_serve(s, &cfg, 1, ServeOptions {
            checkpoint_every: 1,
            checkpoint_path: Some(ckpt.clone()),
            halt_after: 1,
            ..Default::default()
        });
        assert!(r.is_err());
        assert!(ckpt.exists());

        let mut other = cfg.clone();
        other.lr_client = 5e-3; // different experiment
        let (r2, _) = net_serve(s, &other, 1, ServeOptions {
            restore: Some(ckpt.clone()),
            ..Default::default()
        });
        let err = r2.err().expect("mismatched restore must fail");
        assert!(
            format!("{err:#}").contains("different config"),
            "unexpected error: {err:#}"
        );
        let _ = std::fs::remove_file(&ckpt);
    });
}

/// A pending shutdown signal (raised through the safe test hook) makes
/// `serve` write a final boundary checkpoint, broadcast a clean
/// `Shutdown`, and return Ok — an interrupted run is a restorable exit,
/// not an error.
#[test]
fn signal_request_checkpoints_and_shuts_down_cleanly() {
    with_session(|s| {
        let cfg = chaos_cfg(5);
        let ckpt = ckpt_path("signal");
        let _ = std::fs::remove_file(&ckpt);
        signal::reset();
        signal::request(); // pending before round 0: deterministic
        let (r, clients) = net_serve(s, &cfg, 2, ServeOptions {
            checkpoint_path: Some(ckpt.clone()),
            watch_signals: true,
            ..Default::default()
        });
        signal::reset();
        let rep = r.expect("signal shutdown is clean, not an error");
        assert_eq!(rep.record.rounds.len(), 0, "stopped before round 0");
        assert_eq!(rep.record.summary.get("interrupted"), Some(&1.0));
        for c in &clients {
            assert!(
                c.shutdown_reason.contains("signal"),
                "client saw: {}",
                c.shutdown_reason
            );
        }
        let ck = checkpoint::load(&ckpt).expect("final checkpoint loads");
        assert_eq!(ck.state.round_idx, 0);
        assert_eq!(ck.cfg_json, cfg.to_json().to_string());
        let _ = std::fs::remove_file(&ckpt);
    });
}
