//! Cross-language integration tests: Rust regenerates the Python-side
//! golden inputs, executes the compiled HLO, and matches the digests the
//! manifest recorded — plus checks the synthetic-data generators agree
//! bit-for-bit (integers) / to ulps (floats).
//!
//! Requires `make artifacts` (the Makefile test target guarantees this).

use heron_sfl::data::{synth_text, synth_vision};
use heron_sfl::runtime::manifest::Manifest;
use heron_sfl::util::json::Value;
use heron_sfl::util::rng::mix64;

mod common;
use common::with_session;

fn synth_golden() -> Value {
    with_session(|s| s.manifest.synth.clone())
}

#[test]
fn mix64_matches_python() {
    let want: u64 = synth_golden()
        .get("mix64_42_0")
        .and_then(Value::as_str)
        .expect("mix64 golden")
        .parse()
        .unwrap();
    assert_eq!(mix64(42, 0), want);
}

#[test]
fn vision_labels_match_python() {
    let want = synth_golden()
        .get("vision_labels_seed42")
        .and_then(Value::usize_vec)
        .expect("labels golden");
    let got: Vec<usize> = (0..want.len())
        .map(|i| synth_vision::label(42, i as u64) as usize)
        .collect();
    assert_eq!(got, want);
}

#[test]
fn vision_image_matches_python_to_ulps() {
    let img = synth_vision::image(42, 0);
    let want_sum = synth_golden()
        .get("vision_img0_sum")
        .and_then(Value::as_f64)
        .unwrap();
    let got_sum: f64 = img.iter().map(|&v| v as f64).sum();
    assert!(
        (got_sum - want_sum).abs() < 1e-3,
        "sum {got_sum} vs python {want_sum}"
    );
    let first = synth_golden()
        .get("vision_img0_first")
        .and_then(Value::f64_vec)
        .unwrap();
    for (i, w) in first.iter().enumerate() {
        assert!(
            (img[i] as f64 - w).abs() < 1e-6,
            "pixel {i}: {} vs {w}",
            img[i]
        );
    }
}

#[test]
fn text_record_matches_python_exactly() {
    let g = synth_golden();
    let want = g.get("text_record0").and_then(Value::as_str).unwrap();
    assert_eq!(synth_text::record(42, 0), want);
}

#[test]
fn text_tokens_match_python_exactly() {
    let want = synth_golden()
        .get("text_tokens0")
        .and_then(Value::usize_vec)
        .unwrap();
    let toks = synth_text::batch(42, 0, 1);
    for (i, w) in want.iter().enumerate() {
        assert_eq!(toks[i] as usize, *w, "token {i}");
    }
}

#[test]
fn golden_vec_matches_python() {
    let want = synth_golden()
        .get("golden_vec8_salt101")
        .and_then(Value::f64_vec)
        .unwrap();
    let got = heron_sfl::golden::golden_vec(8, 101);
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(*g as f64, *w);
    }
}

// ---------------------------------------------------------------------------
// entry-level goldens through PJRT — the full pipeline proof
// ---------------------------------------------------------------------------

fn check_all(variant: &str) {
    with_session(|session| {
        let v = session.manifest.variant(variant).unwrap();
        assert!(!v.golden.is_empty(), "no goldens for {variant}");
        for entry in v.golden.keys() {
            let rel =
                heron_sfl::golden::check_entry(session, variant, entry)
                    .unwrap_or_else(|e| panic!("{variant}/{entry}: {e:#}"));
            assert!(rel < 5e-3, "{variant}/{entry}: rel err {rel}");
        }
    })
}

#[test]
fn golden_cnn_c1_all_entries() {
    check_all("cnn_c1");
}

#[test]
fn golden_cnn_c2_core_entries() {
    check_all("cnn_c2");
}

#[test]
fn golden_gpt2nano_full_entries() {
    check_all("gpt2nano_c1_a1");
}

#[test]
fn golden_gpt2micro_entries() {
    check_all("gpt2micro_c2_a1");
}

#[test]
fn golden_pallas_kernel_path() {
    // the kernel-path artifact lowers the Pallas lora_linear into the same
    // HLO — digests must match just like the jnp path
    check_all("gpt2nano_c1_a1_pallas");
}

#[test]
fn manifest_structure_sane() {
    let m = Manifest::load_default().unwrap();
    assert!(m.variants.len() >= 10);
    for (name, v) in &m.variants {
        assert!(v.batch > 0, "{name}");
        assert!(v.size_client > 0, "{name}");
        assert!(v.entries.contains_key("eval_full"), "{name}");
        for (ename, e) in &v.entries {
            assert!(
                e.file.exists(),
                "{name}/{ename}: missing {}",
                e.file.display()
            );
            assert!(!e.inputs.is_empty() && !e.outputs.is_empty());
        }
        // init blobs load and have the manifest sizes
        let l = v.blob("init_theta_l").unwrap();
        assert_eq!(l.len(), v.size_local(), "{name} init_theta_l");
        let s = v.blob("init_theta_s").unwrap();
        assert_eq!(s.len(), v.size_server, "{name} init_theta_s");
        if v.size_base > 0 {
            assert_eq!(
                v.blob("frozen_base").unwrap().len(),
                v.size_base,
                "{name} frozen_base"
            );
        }
    }
}
