//! Allocation profile of the zero-allocation invoke path.
//!
//! A counting global allocator measures the bytes allocated inside single
//! `invoke_into` calls. Two properties are pinned:
//!
//! * `zo_step` temporary allocation is **independent of `n_pert`** — the
//!   chunked probe streaming never materializes a per-probe vector, so
//!   16 probes allocate the same handful of scratch buffers as 1;
//! * with a warm feature cache, a `zo_step` invocation allocates far less
//!   than the parameter+feature footprint it used to clone per call.
//!
//! This file holds exactly one test so no concurrent test pollutes the
//! global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use heron_sfl::golden;
use heron_sfl::runtime::tensor::{TensorRef, TensorValue};
use heron_sfl::runtime::Session;

fn bytes_now() -> u64 {
    ALLOCATED.load(Ordering::Relaxed)
}

#[test]
fn zo_step_allocation_independent_of_n_pert() {
    let session = Session::open_default().expect("session");
    for variant in ["cnn_c1", "gpt2nano_c1_a1"] {
        let v = session.manifest.variant(variant).unwrap().clone();
        let espec = v.entry("zo_step").unwrap().clone();
        let pert_idx = espec
            .inputs
            .iter()
            .position(|s| s.name == "n_pert")
            .expect("zo_step has n_pert");
        let mut inputs: Vec<TensorValue> = espec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                golden::bench_input(&session, variant, spec, i, &v.task)
                    .unwrap()
            })
            .collect();

        let mut outs: Vec<TensorValue> = Vec::new();
        let mut measure = |n_pert: i32, outs: &mut Vec<TensorValue>| {
            inputs[pert_idx] = TensorValue::ScalarI32(n_pert);
            let refs: Vec<TensorRef> =
                inputs.iter().map(|t| t.view()).collect();
            // warm: populate the feature cache and size every scratch /
            // slot buffer for this probe count
            session
                .invoke_into(variant, "zo_step", &refs, outs)
                .expect("warm invoke");
            let before = bytes_now();
            session
                .invoke_into(variant, "zo_step", &refs, outs)
                .expect("measured invoke");
            bytes_now() - before
        };

        let one = measure(1, &mut outs);
        let many = measure(16, &mut outs);
        // d parameters * 4 bytes is the per-probe cost the old
        // implementation paid 16x; the chunked path must not scale
        let d_bytes = (v.size_local() * 4) as u64;
        assert!(
            many <= one + 4096,
            "{variant}: zo_step allocations scale with n_pert \
             (1 probe: {one} B, 16 probes: {many} B)"
        );
        assert!(
            many < one + 15 * d_bytes,
            "{variant}: 16-probe step allocated {many} B vs {one} B — \
             per-probe vectors are back"
        );
    }
}
