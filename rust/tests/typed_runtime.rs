//! The typed `ClientRuntime` surface (runtime/api.rs):
//!
//! * trait calls are bit-identical to the name-based entry path for the
//!   step methods the coordinator drives;
//! * `zo_step` hands back the per-probe gradient scalars, and
//!   `(seed, gscales)` alone replays θ' bit-identically
//!   (`zo::stream::replay_update`) — the `--zo_wire seeds` contract;
//! * a drifted manifest (stale output slot, renamed tensor, unknown
//!   entry, reordered inputs) fails at `Session::new`, not at first
//!   invoke — the stale-slot hazard class is closed at the root.

use heron_sfl::golden;
use heron_sfl::runtime::api::{ZoArgs, ZoStepRecord};
use heron_sfl::runtime::tensor::TensorValue;
use heron_sfl::runtime::Session;
use heron_sfl::zo::stream::replay_update;

mod common;
use common::with_session;

/// Pull a named input out of the golden input list for an entry.
fn named_input(
    s: &Session,
    variant: &str,
    entry: &str,
    name: &str,
) -> Option<TensorValue> {
    let v = s.manifest.variant(variant).unwrap();
    let espec = v.entry(entry).unwrap();
    espec
        .inputs
        .iter()
        .position(|sp| sp.name == name)
        .map(|i| {
            golden::bench_input(s, variant, &espec.inputs[i], i, &v.task)
                .unwrap()
        })
}

fn as_i32_vec(v: TensorValue) -> Vec<i32> {
    match v {
        TensorValue::I32(x) => x,
        other => panic!("expected i32 tensor, got {other:?}"),
    }
}

#[test]
fn typed_zo_step_matches_entry_and_replays_bitwise() {
    with_session(|s| {
        for variant in ["cnn_c1", "gpt2nano_c1_a1"] {
            let v = s.manifest.variant(variant).unwrap().clone();
            let espec = v.entry("zo_step").unwrap().clone();
            let inputs: Vec<TensorValue> = espec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, sp)| {
                    golden::bench_input(s, variant, sp, i, &v.task).unwrap()
                })
                .collect();
            let entry_outs = s.invoke(variant, "zo_step", &inputs).unwrap();
            let ti = espec.output_pos("theta_l").unwrap();
            let li = espec.output_pos("loss").unwrap();
            let want_theta = entry_outs[ti].as_f32().unwrap();
            let want_loss = entry_outs[li].scalar_f32().unwrap();

            // rebuild the same arguments for the typed call
            let get = |n: &str| named_input(s, variant, "zo_step", n);
            let base: Option<Vec<f32>> =
                get("base").map(|b| b.into_f32().unwrap());
            let theta = get("theta_l").unwrap().into_f32().unwrap();
            let x = get("x").unwrap();
            let y = as_i32_vec(get("y").unwrap());
            let seed = match get("seed").unwrap() {
                TensorValue::ScalarI32(v) => v,
                TensorValue::I32(v) => v[0],
                other => panic!("seed: {other:?}"),
            };
            let mu = get("mu").unwrap().scalar_f32().unwrap();
            let lr = get("lr").unwrap().scalar_f32().unwrap();
            let n_pert = match get("n_pert").unwrap() {
                TensorValue::ScalarI32(v) => v,
                TensorValue::I32(v) => v[0],
                other => panic!("n_pert: {other:?}"),
            };

            let rt = s.client_runtime(variant).unwrap();
            let layout = rt.layout();
            assert_eq!(layout.nl(), v.size_local(), "{variant}: layout");
            assert_eq!(layout.ns, v.size_server);
            assert_eq!(layout.nb, v.size_base);

            let mut out = Vec::new();
            let mut rec = ZoStepRecord::default();
            rt.zo_step(
                base.as_deref(),
                &theta,
                x.view(),
                &y,
                ZoArgs { seed, mu, lr, n_pert },
                &mut out,
                &mut rec,
            )
            .unwrap();

            // typed == entry, bit for bit
            assert_eq!(out.len(), want_theta.len(), "{variant}: θ' length");
            for (i, (a, b)) in out.iter().zip(want_theta).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{variant}: θ'[{i}]");
            }
            assert_eq!(
                rec.loss.to_bits(),
                want_loss.to_bits(),
                "{variant}: loss"
            );
            assert_eq!(rec.seed, seed);
            assert_eq!(
                rec.gscales.len(),
                n_pert.max(1) as usize,
                "{variant}: one gscale per probe"
            );

            // the lean record alone replays the update bit for bit
            let mut replayed = Vec::new();
            replay_update(&theta, seed, &rec.gscales, &mut replayed);
            assert_eq!(replayed.len(), out.len());
            for (i, (a, b)) in replayed.iter().zip(&out).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{variant}: replay[{i}]"
                );
            }
        }
    })
}

#[test]
fn typed_eval_matches_entry() {
    with_session(|s| {
        for variant in ["cnn_c1", "gpt2micro_c2_a1"] {
            let v = s.manifest.variant(variant).unwrap().clone();
            let espec = v.entry("eval_full").unwrap().clone();
            let inputs: Vec<TensorValue> = espec
                .inputs
                .iter()
                .enumerate()
                .map(|(i, sp)| {
                    golden::bench_input(s, variant, sp, i, &v.task).unwrap()
                })
                .collect();
            let outs = s.invoke(variant, "eval_full", &inputs).unwrap();
            let want1 = outs[0].scalar_f32().unwrap();
            let want2 = outs[1].scalar_f32().unwrap();
            let get = |n: &str| named_input(s, variant, "eval_full", n);
            let base: Option<Vec<f32>> =
                get("base").map(|b| b.into_f32().unwrap());
            let theta_c = get("theta_c").unwrap().into_f32().unwrap();
            let theta_s = get("theta_s").unwrap().into_f32().unwrap();
            let x = get("x").unwrap();
            let y = as_i32_vec(get("y").unwrap());
            let rt = s.client_runtime(variant).unwrap();
            let (s1, s2) = rt
                .eval_full(base.as_deref(), &theta_c, &theta_s, x.view(), &y)
                .unwrap();
            assert_eq!(s1.to_bits(), want1.to_bits(), "{variant}: stat1");
            assert_eq!(s2.to_bits(), want2.to_bits(), "{variant}: stat2");
        }
    })
}

#[test]
fn drifted_manifest_fails_at_session_new() {
    with_session(|s| {
        // a faithful clone still constructs
        Session::new(s.manifest.clone()).unwrap();

        // stale extra output slot (the PR-2 hazard, now caught at new)
        let mut m = s.manifest.clone();
        {
            let v = m.variants.get_mut("cnn_c1").unwrap();
            let e = v.entries.get_mut("zo_step").unwrap();
            let extra = e.outputs[0].clone();
            e.outputs.push(extra);
        }
        let err = format!("{:#}", Session::new(m).unwrap_err());
        assert!(err.contains("zo_step"), "should name the entry: {err}");

        // renamed output
        let mut m = s.manifest.clone();
        m.variants
            .get_mut("cnn_c1")
            .unwrap()
            .entries
            .get_mut("fo_step")
            .unwrap()
            .outputs[0]
            .name = "theta".into();
        assert!(Session::new(m).is_err());

        // unknown entry name
        let mut m = s.manifest.clone();
        {
            let v = m.variants.get_mut("cnn_c1").unwrap();
            let mut bogus = v.entries.get("zo_step").unwrap().clone();
            bogus.name = "zo_step_v2".into();
            v.entries.insert("zo_step_v2".into(), bogus);
        }
        let err = format!("{:#}", Session::new(m).unwrap_err());
        assert!(err.contains("zo_step_v2"), "{err}");

        // dropped input
        let mut m = s.manifest.clone();
        m.variants
            .get_mut("cnn_c1")
            .unwrap()
            .entries
            .get_mut("client_fwd")
            .unwrap()
            .inputs
            .pop();
        assert!(Session::new(m).is_err());
    })
}
