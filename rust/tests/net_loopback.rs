//! End-to-end tests of the `heron-net` subsystem over in-memory loopback
//! transports (every frame still encodes/decodes, so byte counters
//! measure the real wire format):
//!
//! * **bit-identity** — for every algorithm, a networked run (multiple
//!   client "processes" on threads) reproduces the in-process
//!   `Driver::run` trajectory bit for bit;
//! * **accounting cross-check** — measured wire bytes per round equal the
//!   analytic `CostBook` comm bytes plus an explicitly pinned protocol
//!   overhead (frame headers, acks, barriers, targets, …), so silent
//!   drift between `accounting.rs` and the real protocol fails a test;
//! * **NACK failure injection** — a pinned queue capacity makes the
//!   server drop uploads; the typed NACKs seen by clients must equal the
//!   server-side drop count in `QueueStats`.

use heron_sfl::coordinator::accounting::CostBook;
use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::{RunConfig, ZoWireMode};
use heron_sfl::coordinator::round::Driver;
use heron_sfl::net::codec::{self, Codec, GradCodec};
use heron_sfl::net::transport::{loopback_pair, Transport};
use heron_sfl::net::wire::FRAME_OVERHEAD;
use heron_sfl::net::{
    run_client, run_client_virtual, serve_transports, ClientReport,
    NetReport,
};
use heron_sfl::runtime::Session;

mod common;
use common::with_session;

fn cfg(alg: Algorithm, n_clients: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: alg,
        n_clients,
        rounds: 2,
        local_steps: 4,
        upload_every: 2,
        align_every: 1, // FSL-SAGE: every upload produces cut-grad feedback
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        workers: 1,
        ..Default::default()
    }
}

/// Run the experiment over `n_conns` loopback connections, clients on
/// threads — the in-memory analogue of `serve` + N × `connect`.
fn net_run(
    session: &Session,
    cfg: &RunConfig,
    n_conns: usize,
) -> (NetReport, Vec<ClientReport>) {
    let mut server_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut client_ends = Vec::new();
    for _ in 0..n_conns {
        let (s, c) = loopback_pair();
        server_ends.push(Box::new(s));
        client_ends.push(c);
    }
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_transports(session, cfg.clone(), server_ends, "net")
        });
        let clients: Vec<_> = client_ends
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                scope.spawn(move || {
                    run_client(session, Box::new(c), &format!("edge-{i}"))
                })
            })
            .collect();
        let report = server.join().expect("server panicked").expect("server");
        let client_reports = clients
            .into_iter()
            .map(|h| h.join().expect("client panicked").expect("client"))
            .collect();
        (report, client_reports)
    })
}

/// Like [`net_run`], but each connection multiplexes `lanes` virtual
/// clients through its single transport (`connect --virtual lanes`).
fn net_run_virtual(
    session: &Session,
    cfg: &RunConfig,
    n_conns: usize,
    lanes: usize,
) -> (NetReport, Vec<ClientReport>) {
    let mut server_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut client_ends = Vec::new();
    for _ in 0..n_conns {
        let (s, c) = loopback_pair();
        server_ends.push(Box::new(s));
        client_ends.push(c);
    }
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_transports(session, cfg.clone(), server_ends, "net")
        });
        let clients: Vec<_> = client_ends
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                scope.spawn(move || {
                    run_client_virtual(
                        session,
                        Box::new(c),
                        &format!("mux-{i}"),
                        lanes,
                    )
                })
            })
            .collect();
        let report = server.join().expect("server panicked").expect("server");
        let client_reports = clients
            .into_iter()
            .map(|h| h.join().expect("client panicked").expect("client"))
            .collect();
        (report, client_reports)
    })
}

fn in_process(
    session: &Session,
    cfg: &RunConfig,
) -> (heron_sfl::metrics::RunRecord, Vec<f32>, Vec<f32>) {
    let mut driver = Driver::new(session, cfg.clone()).unwrap();
    let rec = driver.run("inproc").unwrap();
    (rec, driver.theta_l.clone(), driver.theta_s.clone())
}

fn assert_trajectories_match(alg: Algorithm, n_conns: usize, n_clients: usize) {
    with_session(|s| {
        let c = cfg(alg, n_clients);
        let (rec, theta_l, theta_s) = in_process(s, &c);
        let (net, _) = net_run(s, &c, n_conns);
        let name = alg.name();
        assert_eq!(
            net.record.rounds.len(),
            rec.rounds.len(),
            "{name}: round count"
        );
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{name}: train loss, round {}",
                a.round
            );
            assert_eq!(
                a.eval_metric.to_bits(),
                b.eval_metric.to_bits(),
                "{name}: eval metric, round {}",
                a.round
            );
            assert_eq!(
                a.comm_bytes_cum, b.comm_bytes_cum,
                "{name}: analytic comm, round {}",
                a.round
            );
        }
        assert_eq!(theta_l, net.final_theta_l, "{name}: θ_l");
        assert_eq!(theta_s, net.final_theta_s, "{name}: θ_s");
        assert_eq!(
            rec.summary["comm_bytes"], net.record.summary["comm_bytes"],
            "{name}: summary comm"
        );
        assert_eq!(
            rec.summary["client_flops"], net.record.summary["client_flops"],
            "{name}: summary flops"
        );
        // the networked run must actually have moved bytes
        assert!(net.wire.bytes_sent > 0 && net.wire.bytes_recv > 0);
        assert!(
            net.record.summary["wire_bytes_sent"] > 0.0,
            "{name}: per-round wire stats missing"
        );
        // in-process runs report zero measured wire traffic
        assert_eq!(rec.summary["wire_bytes_sent"], 0.0);
    });
}

#[test]
fn heron_tcp_loopback_bit_identical_two_conns() {
    // 4 logical clients round-robined over 2 client processes
    assert_trajectories_match(Algorithm::Heron, 2, 4);
}

#[test]
fn cse_fsl_bit_identical() {
    assert_trajectories_match(Algorithm::CseFsl, 2, 4);
}

#[test]
fn fsl_sage_bit_identical_including_alignment() {
    assert_trajectories_match(Algorithm::FslSage, 2, 4);
}

#[test]
fn sflv1_bit_identical_locked_path() {
    assert_trajectories_match(Algorithm::SflV1, 2, 3);
}

#[test]
fn sflv2_bit_identical_locked_path() {
    assert_trajectories_match(Algorithm::SflV2, 2, 3);
}

#[test]
fn partial_participation_keeps_identity_with_idle_conns() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 5);
        c.participation = 0.6; // 3 of 5 participate; some conns sit idle
        c.rounds = 3;
        let (rec, theta_l, _) = in_process(s, &c);
        let (net, _) = net_run(s, &c, 3);
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
        assert_eq!(theta_l, net.final_theta_l);
    });
}

// ---------------------------------------------------------------------------
// accounting cross-check: measured wire bytes vs analytic CostBook
// ---------------------------------------------------------------------------

/// Expected measured bytes per round, derived from the protocol layout.
/// Run with one logical client per connection so the θ broadcast maps
/// 1:1 onto the analytic per-participant sync (with multiple clients per
/// connection the broadcast amortizes and measured < analytic — that gap
/// is the point of measuring).
struct Expected {
    sent: u64, // server -> clients
    recv: u64, // clients -> server
}

fn expected_round_bytes(
    s: &Session,
    c: &RunConfig,
    n_conns: usize,
    align_msgs: u64,
) -> Expected {
    let v = s.variant(&c.variant).unwrap();
    let nl = v.size_local() as u64;
    let book = heron_sfl::coordinator::accounting::CostBook::new(
        v,
        c.algorithm,
        c.n_pert as u64,
    )
    .with_codec(c.codec, c.grad_codec);
    let p = c.n_clients as u64; // participation = 1.0 here
    let conns = n_conns as u64;
    let h = c.local_steps as u64;
    let uploads = h / c.upload_every as u64;
    let targets = v.batch as u64; // vision: one i32 label per sample
    let f = FRAME_OVERHEAD;

    let lean = c.zo_wire.lean_uplink();
    // seeds mode ships the flattened h x n_p per-probe scalars; theta
    // mode ships an empty gscales vector (4-byte length prefix only)
    let gs_elems = if lean { h * c.n_pert.max(1) as u64 } else { 0 };

    let barrier = f + 8 + 4 * p; // round + vec<u32> participants
    let summary = f + 28;
    // v4: every routed frame carries the 4-byte lane id up front
    let model_down = f + 16 + 4 * nl; // lane + round + client + vec<f32> θ
    let model_up = model_down;
    // ids(16, lane included) + two length-prefixed vectors: the v6
    // smashed envelope (vec<u8>: codec header + the CostBook's
    // information bytes) and the target i32s — the codec header is
    // exactly the "explicit per-message overhead" of this cross-check
    let smashed = f + 24
        + codec::header_bytes(c.codec)
        + book.smashed_bytes
        + 4 * targets;
    let ack = f + 17; // ids + bool + empty reason string
    // ids (lane + client + round) + seeds + scalars + gscales
    let zo_update =
        f + 12 + (4 + 4 * h) + (4 + 4 * h) + (4 + 4 * gs_elems);
    let local_done = f + 44;
    // ids + loss + the v6 cut-gradient envelope (vec<u8>)
    let cut_grad = f + 20
        + codec::header_bytes_grad(c.grad_codec)
        + book.cutgrad_bytes;
    // AlignGrad stays a raw vec<f32> (not a codec envelope): ids + g
    let align_grad = f + 12 + book.cutgrad_bytes;

    if c.algorithm.is_decoupled() {
        // seeds mode: the ZoUpdate record replaces the θ upload entirely
        let model_ups = if lean { 0 } else { p };
        Expected {
            sent: conns * (barrier + summary)
                + conns * model_down
                + p * uploads * ack
                + align_msgs * align_grad,
            recv: p * uploads * smashed
                + p * (zo_update + local_done)
                + model_ups * model_up
                + align_msgs * model_up,
        }
    } else {
        Expected {
            sent: conns * (barrier + summary)
                + p * model_down // per-participant locked kickoff
                + p * h * cut_grad,
            recv: p * h * smashed + p * model_up,
        }
    }
}

/// Measured loopback bytes for `c` (one logical client per connection)
/// vs the analytic `CostBook` formulas — codec-aware on both sides: the
/// book carries the compressed information bytes, the expected wire
/// layout adds the codec header as explicit per-message overhead.
fn assert_measured_bytes_match(
    s: &Session,
    c: &RunConfig,
    n_clients: usize,
) {
    let tag = format!(
        "{}/{}/{}",
        c.algorithm.name(),
        c.codec.name(),
        c.grad_codec.spec()
    );
    let (net, _) = net_run(s, c, n_clients); // 1 client per conn
    let v = s.variant(&c.variant).unwrap();
    let book = heron_sfl::coordinator::accounting::CostBook::new(
        v,
        c.algorithm,
        c.n_pert as u64,
    )
    .with_codec(c.codec, c.grad_codec);
    // FSL-SAGE emits one feedback per cut-grad upload: uploads at
    // steps k, 2k, ... where step % (k * align_every) == 0
    let uploads = (c.local_steps / c.upload_every) as u64;
    let align_msgs = if c.algorithm == Algorithm::FslSage {
        n_clients as u64 * uploads
    } else {
        0
    };
    let want = expected_round_bytes(s, c, n_clients, align_msgs);

    // the analytic CostBook number for the same round, from the
    // same formulas the in-process counter uses
    let p = n_clients as u64;
    let analytic_round = match c.algorithm {
        Algorithm::SflV1 | Algorithm::SflV2 => {
            p * (c.local_steps as u64
                * (book.smashed_bytes + book.cutgrad_bytes)
                + book.comm_per_round_sync())
        }
        _ => {
            p * (uploads * book.smashed_bytes
                + book.comm_per_round_sync())
                + align_msgs * book.cutgrad_bytes
        }
    };

    for (round, t) in net.record.rounds.iter().enumerate() {
        let delta = if round == 0 {
            t.comm_bytes_cum
        } else {
            t.comm_bytes_cum
                - net.record.rounds[round - 1].comm_bytes_cum
        };
        assert_eq!(
            delta, analytic_round,
            "{tag}: analytic round formula drifted (round {round})"
        );
    }

    // measured per-round traffic (server view), recorded in the
    // run summary as cumulative sums over RoundTiming.wire
    let rounds = c.rounds as u64;
    let measured_sent = net.record.summary["wire_bytes_sent"] as u64;
    let measured_recv = net.record.summary["wire_bytes_recv"] as u64;
    assert_eq!(
        measured_sent,
        want.sent * rounds,
        "{tag}: server->client bytes (analytic {} + overhead {})",
        analytic_round,
        want.sent as i64 - analytic_round as i64,
    );
    assert_eq!(
        measured_recv,
        want.recv * rounds,
        "{tag}: client->server bytes"
    );
}

#[test]
fn measured_wire_bytes_match_analytic_plus_pinned_overhead() {
    with_session(|s| {
        for alg in Algorithm::all() {
            assert_measured_bytes_match(s, &cfg(alg, 3), 3);
        }
    });
}

// ---------------------------------------------------------------------------
// payload codecs (v6): pinned identity, lossy legs, per-codec accounting
// ---------------------------------------------------------------------------

/// Per-codec accounting cross-check: for every algorithm that ships
/// smashed payloads, the measured loopback bytes under each lossy codec
/// equal the CostBook's compressed formula plus the explicit codec
/// header overhead — and the top-k cut-gradient legs likewise on both
/// locked baselines. (The f32 legs are covered above: `Codec::F32` is
/// the default every other loopback test runs under.)
#[test]
fn measured_wire_bytes_match_analytic_for_every_codec() {
    with_session(|s| {
        for alg in Algorithm::all() {
            for smashed_codec in [Codec::Int8, Codec::Int4] {
                let mut c = cfg(alg, 3);
                c.codec = smashed_codec;
                assert_measured_bytes_match(s, &c, 3);
            }
        }
        for alg in [Algorithm::SflV1, Algorithm::SflV2] {
            let mut c = cfg(alg, 3);
            c.codec = Codec::Int8;
            c.grad_codec = GradCodec::TopK(0.25);
            assert_measured_bytes_match(s, &c, 3);
        }
    });
}

/// The encode-once rule, end to end: under a lossy codec the networked
/// run must still be bit-identical to the in-process driver — the
/// quantization happens exactly once at the producer, so both paths see
/// the same post-roundtrip values.
fn assert_codec_net_matches_in_process(c: &RunConfig, n_conns: usize) {
    with_session(|s| {
        let tag = format!(
            "{}/{}/{}",
            c.algorithm.name(),
            c.codec.name(),
            c.grad_codec.spec()
        );
        let (rec, theta_l, theta_s) = in_process(s, c);
        let (net, _) = net_run(s, c, n_conns);
        assert_eq!(rec.rounds.len(), net.record.rounds.len(), "{tag}");
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{tag}: train loss, round {}",
                a.round
            );
            assert_eq!(
                a.eval_metric.to_bits(),
                b.eval_metric.to_bits(),
                "{tag}: eval metric, round {}",
                a.round
            );
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum, "{tag}");
        }
        assert_eq!(theta_l, net.final_theta_l, "{tag}: θ_l");
        assert_eq!(theta_s, net.final_theta_s, "{tag}: θ_s");
    });
}

#[test]
fn int8_codec_net_run_bit_identical_for_every_algorithm() {
    for alg in Algorithm::all() {
        let mut c = cfg(alg, 3);
        c.codec = Codec::Int8;
        assert_codec_net_matches_in_process(&c, 3);
    }
}

#[test]
fn int4_codec_net_run_bit_identical_decoupled_and_locked() {
    for alg in [Algorithm::Heron, Algorithm::SflV2] {
        let mut c = cfg(alg, 3);
        c.codec = Codec::Int4;
        assert_codec_net_matches_in_process(&c, 3);
    }
}

#[test]
fn topk_cut_gradient_net_run_bit_identical_locked() {
    for alg in [Algorithm::SflV1, Algorithm::SflV2] {
        let mut c = cfg(alg, 3);
        c.grad_codec = GradCodec::TopK(0.25);
        assert_codec_net_matches_in_process(&c, 3);
    }
}

/// The Pareto direction, measured: the lossy legs put strictly fewer
/// bytes on the wire than the f32 identity leg — and on the decoupled
/// path the client-phase train losses stay *bitwise* equal to f32,
/// because quantizing the smashed upload only perturbs the server/eval
/// side, never the client's local step.
#[test]
fn lossy_codecs_slim_measured_wire_and_keep_client_losses() {
    with_session(|s| {
        let base = cfg(Algorithm::Heron, 3);
        let (f32_net, _) = net_run(s, &base, 3);
        for smashed_codec in [Codec::Int8, Codec::Int4] {
            let mut c = base.clone();
            c.codec = smashed_codec;
            let (net, _) = net_run(s, &c, 3);
            assert!(
                net.wire.bytes_recv < f32_net.wire.bytes_recv,
                "{}: measured upload {} not below f32 {}",
                smashed_codec.name(),
                net.wire.bytes_recv,
                f32_net.wire.bytes_recv
            );
            assert!(
                net.record.summary["comm_bytes"]
                    < f32_net.record.summary["comm_bytes"],
                "{}: analytic comm not lean",
                smashed_codec.name()
            );
            for (a, b) in
                f32_net.record.rounds.iter().zip(&net.record.rounds)
            {
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{}: decoupled train loss must not feel the smashed \
                     codec (round {})",
                    smashed_codec.name(),
                    a.round
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// lean wire mode (--zo_wire seeds): replayed trajectory + lean bytes
// ---------------------------------------------------------------------------

/// `seeds` vs `theta` wire modes: byte-identical θ trajectories (the
/// server-side replay is exact), and the seeds run is additionally
/// bit-identical to an in-process run of the same config — analytic
/// accounting included.
fn assert_seeds_mode_bit_identical(variant: &str, n_clients: usize) {
    with_session(|s| {
        let mut c_theta = cfg(Algorithm::Heron, n_clients);
        c_theta.variant = variant.into();
        c_theta.n_pert = 2;
        let mut c_seeds = c_theta.clone();
        c_seeds.zo_wire = ZoWireMode::Seeds;
        let (net_t, _) = net_run(s, &c_theta, 2);
        let (net_s, _) = net_run(s, &c_seeds, 2);
        assert_eq!(
            net_t.final_theta_l, net_s.final_theta_l,
            "{variant}: replayed θ_l diverged"
        );
        assert_eq!(
            net_t.final_theta_s, net_s.final_theta_s,
            "{variant}: θ_s diverged"
        );
        for (a, b) in net_t.record.rounds.iter().zip(&net_s.record.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{variant}: train loss, round {}",
                a.round
            );
            assert_eq!(
                a.eval_metric.to_bits(),
                b.eval_metric.to_bits(),
                "{variant}: eval metric, round {}",
                a.round
            );
        }
        // lean analytic accounting: the seeds run moves (and books)
        // strictly fewer bytes than the theta run
        assert!(
            net_s.record.summary["comm_bytes"]
                < net_t.record.summary["comm_bytes"],
            "{variant}: seeds-mode analytic comm is not lean"
        );
        assert!(
            net_s.wire.bytes_recv < net_t.wire.bytes_recv,
            "{variant}: seeds-mode measured upload is not lean"
        );
        // and the seeds net run == the in-process run of the same config,
        // bit for bit, analytic counters included
        let (rec, theta_l, theta_s) = in_process(s, &c_seeds);
        assert_eq!(theta_l, net_s.final_theta_l, "{variant}: θ_l");
        assert_eq!(theta_s, net_s.final_theta_s, "{variant}: θ_s");
        for (a, b) in rec.rounds.iter().zip(&net_s.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
    });
}

#[test]
fn zo_wire_seeds_bit_identical_vision() {
    assert_seeds_mode_bit_identical("cnn_c1", 4);
}

#[test]
fn zo_wire_seeds_bit_identical_lm() {
    assert_seeds_mode_bit_identical("gpt2nano_c1_a1", 3);
}

/// The title claim, measured: with `--zo_wire seeds` the bytes clients
/// actually put on the wire per round sit strictly below the analytic
/// `2(|θc|+|θa|)` ModelSync cost of Table I — below even ONE direction
/// of it, for the whole cohort combined, frame overhead included.
#[test]
fn seeds_mode_upload_beats_model_sync_cost() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 3);
        c.zo_wire = ZoWireMode::Seeds;
        c.local_steps = 3;
        c.upload_every = 4; // no smashed uploads this round shape
        c.n_pert = 2;
        let (net, _) = net_run(s, &c, 3);
        let v = s.variant(&c.variant).unwrap();
        let nl_bytes = (v.size_local() * 4) as u64;
        let rounds = c.rounds as u64;
        let per_round_up =
            net.record.summary["wire_bytes_recv"] as u64 / rounds;
        assert!(
            per_round_up < 2 * nl_bytes,
            "measured c→s {per_round_up} B/round >= analytic sync {} B",
            2 * nl_bytes
        );
        assert!(
            per_round_up < nl_bytes,
            "measured c→s {per_round_up} B/round should beat even one \
             θ_l upload ({nl_bytes} B)"
        );
        // the trajectory is still the real one: losses move
        assert_eq!(net.record.rounds.len(), c.rounds);
    });
}

/// Accounting cross-check for the lean mode: measured `ZoUpdate{seeds}`
/// traffic equals the analytic per-probe scalar count plus the pinned
/// per-message overhead formula, and the CostBook round formula matches
/// the recorded analytic deltas exactly.
#[test]
fn measured_seeds_wire_bytes_match_formula() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 3);
        c.zo_wire = ZoWireMode::Seeds;
        c.n_pert = 2;
        let n_clients = 3;
        let (net, _) = net_run(s, &c, n_clients); // 1 client per conn
        let want = expected_round_bytes(s, &c, n_clients, 0);
        let rounds = c.rounds as u64;
        assert_eq!(
            net.record.summary["wire_bytes_sent"] as u64,
            want.sent * rounds,
            "server->client bytes"
        );
        assert_eq!(
            net.record.summary["wire_bytes_recv"] as u64,
            want.recv * rounds,
            "client->server bytes"
        );
        // analytic CostBook round formula with the lean sync
        let v = s.variant(&c.variant).unwrap();
        let book = CostBook::new(v, c.algorithm, c.n_pert as u64)
            .with_zo_wire(
                c.zo_wire,
                c.local_steps as u64,
                c.participants_per_round() as u64,
            );
        let p = n_clients as u64;
        let uploads = (c.local_steps / c.upload_every) as u64;
        let analytic_round =
            p * (uploads * book.smashed_bytes + book.comm_per_round_sync());
        // the lean sync is literally θ_l down + h·(seed + n_p scalars) up
        assert_eq!(
            book.comm_per_round_sync(),
            (v.size_local() * 4) as u64
                + c.local_steps as u64 * (4 + 4 * c.n_pert as u64)
        );
        for (round, t) in net.record.rounds.iter().enumerate() {
            let delta = if round == 0 {
                t.comm_bytes_cum
            } else {
                t.comm_bytes_cum
                    - net.record.rounds[round - 1].comm_bytes_cum
            };
            assert_eq!(
                delta, analytic_round,
                "analytic lean round formula drifted (round {round})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// dimension-free downlink (--zo_wire seed_agg, wire v7 SeedSync)
// ---------------------------------------------------------------------------

/// `seed_agg` vs `seeds` vs `theta`: all three wire modes produce the
/// same trajectory bit for bit (every client reconstructs the
/// aggregated θ_l from the SeedSync roster exactly as the server's own
/// `zo::aggregate_trajectories` does), the seed_agg run books *and*
/// measures strictly fewer broadcast bytes than the seeds run, and the
/// seed_agg net run is additionally bit-identical to the in-process
/// driver — analytic counters included.
fn assert_seed_agg_bit_identical(variant: &str, n_clients: usize) {
    with_session(|s| {
        let mut c_theta = cfg(Algorithm::Heron, n_clients);
        c_theta.variant = variant.into();
        c_theta.n_pert = 2;
        let mut c_seeds = c_theta.clone();
        c_seeds.zo_wire = ZoWireMode::Seeds;
        let mut c_agg = c_theta.clone();
        c_agg.zo_wire = ZoWireMode::SeedAgg;
        let (net_t, _) = net_run(s, &c_theta, 2);
        let (net_s, _) = net_run(s, &c_seeds, 2);
        let (net_a, _) = net_run(s, &c_agg, 2);
        assert_eq!(
            net_t.final_theta_l, net_a.final_theta_l,
            "{variant}: aggregate-replayed θ_l diverged"
        );
        assert_eq!(
            net_t.final_theta_s, net_a.final_theta_s,
            "{variant}: θ_s diverged"
        );
        for (a, b) in net_t.record.rounds.iter().zip(&net_a.record.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{variant}: train loss, round {}",
                a.round
            );
            assert_eq!(
                a.eval_metric.to_bits(),
                b.eval_metric.to_bits(),
                "{variant}: eval metric, round {}",
                a.round
            );
        }
        // the dimension-free downlink, measured: past the bootstrap
        // round the broadcast is the SeedSync roster, so the server puts
        // strictly fewer bytes on the wire than the seeds run (which
        // still broadcasts a dense θ_l every round)
        assert!(
            net_a.wire.bytes_sent < net_s.wire.bytes_sent,
            "{variant}: seed_agg measured downlink {} not below seeds {}",
            net_a.wire.bytes_sent,
            net_s.wire.bytes_sent
        );
        assert!(
            net_a.record.summary["comm_bytes"]
                < net_s.record.summary["comm_bytes"],
            "{variant}: seed_agg analytic comm is not lean"
        );
        // and the seed_agg net run == the in-process run of the same
        // config, bit for bit, analytic counters included
        let (rec, theta_l, theta_s) = in_process(s, &c_agg);
        assert_eq!(theta_l, net_a.final_theta_l, "{variant}: θ_l");
        assert_eq!(theta_s, net_a.final_theta_s, "{variant}: θ_s");
        for (a, b) in rec.rounds.iter().zip(&net_a.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
    });
}

#[test]
fn zo_wire_seed_agg_bit_identical_vision() {
    assert_seed_agg_bit_identical("cnn_c1", 4);
}

#[test]
fn zo_wire_seed_agg_bit_identical_lm() {
    assert_seed_agg_bit_identical("gpt2nano_c1_a1", 3);
}

/// Accounting cross-check for the dimension-free downlink: measured
/// server→client bytes equal the dense bootstrap broadcast in round 0
/// plus the analytic SeedSync roster frame in every later round — and
/// the CostBook's round-indexed sync formula matches the recorded
/// analytic deltas exactly.
#[test]
fn measured_seed_agg_wire_bytes_match_formula() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 3);
        c.zo_wire = ZoWireMode::SeedAgg;
        c.n_pert = 2;
        let n_clients = 3;
        let (net, _) = net_run(s, &c, n_clients); // 1 client per conn
        let v = s.variant(&c.variant).unwrap();
        let nl = v.size_local() as u64;
        let p = n_clients as u64;
        let conns = p;
        let h = c.local_steps as u64;
        let np = c.n_pert as u64;
        let uploads = h / c.upload_every as u64;
        let f = FRAME_OVERHEAD;
        let rounds = c.rounds as u64; // 2: one bootstrap + one SeedSync

        // per-message layouts (same derivation as expected_round_bytes)
        let barrier = f + 8 + 4 * p;
        let summary = f + 28;
        let ack = f + 17;
        let dense_down = f + 16 + 4 * nl;
        // wire v7 SeedSync: round + clients + weights + seeds + gscales
        let seed_down = f + 20 + 12 * p + 4 * (p * h) + 4 * (p * h * np);
        assert!(
            seed_down < dense_down,
            "SeedSync frame {seed_down} B not below dense sync {dense_down} B"
        );
        let book0 = CostBook::new(v, c.algorithm, np);
        let smashed = f + 24
            + codec::header_bytes(c.codec)
            + book0.smashed_bytes
            + 4 * v.batch as u64;
        let zo_update =
            f + 12 + (4 + 4 * h) + (4 + 4 * h) + (4 + 4 * h * np);
        let local_done = f + 44;

        let per_round_base = conns * (barrier + summary) + p * uploads * ack;
        let want_sent =
            per_round_base * rounds + conns * (dense_down + seed_down);
        let per_round_recv =
            p * uploads * smashed + p * (zo_update + local_done);
        assert_eq!(
            net.record.summary["wire_bytes_sent"] as u64,
            want_sent,
            "server->client bytes"
        );
        assert_eq!(
            net.record.summary["wire_bytes_recv"] as u64,
            per_round_recv * rounds,
            "client->server bytes"
        );

        // analytic book, round-indexed: dense bootstrap, then the
        // dimension-free roster — O(cohort·h·n_p), independent of |θ_l|
        let book = CostBook::new(v, c.algorithm, np).with_zo_wire(
            c.zo_wire,
            h,
            c.participants_per_round() as u64,
        );
        assert_eq!(book.downlink_per_round_sync(0), nl * 4);
        assert_eq!(
            book.downlink_per_round_sync(1),
            p * (4 + 8 + h * (4 + 4 * np))
        );
        for (round, t) in net.record.rounds.iter().enumerate() {
            let delta = if round == 0 {
                t.comm_bytes_cum
            } else {
                t.comm_bytes_cum
                    - net.record.rounds[round - 1].comm_bytes_cum
            };
            let analytic_round = p
                * (uploads * book.smashed_bytes
                    + book.comm_per_round_sync_at(round as u64));
            assert_eq!(
                delta, analytic_round,
                "analytic seed_agg round formula drifted (round {round})"
            );
        }
    });
}

// ---------------------------------------------------------------------------
// failure injection: queue capacity → typed NACKs
// ---------------------------------------------------------------------------

#[test]
fn queue_drops_surface_as_typed_nacks() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 3);
        c.upload_every = 1; // 4 uploads per client per round
        c.queue_capacity = 2; // 12 uploads/round contend for 2 slots
        let (net, clients) = net_run(s, &c, 3);
        let dropped = net.record.summary["queue_dropped"] as u64;
        assert!(dropped > 0, "capacity 2 must drop uploads");
        assert_eq!(net.nacks_sent, dropped, "every drop sends one NACK");
        let client_nacks: u64 = clients.iter().map(|r| r.nacks).sum();
        assert_eq!(client_nacks, dropped, "every NACK reaches a client");
        // conservation: every upload is either enqueued or dropped
        let enqueued = net.record.summary["queue_enqueued"] as u64;
        let total_uploads =
            (c.n_clients * c.local_steps * c.rounds) as u64;
        assert_eq!(enqueued + dropped, total_uploads);
        // the run still completes every round
        assert_eq!(net.record.rounds.len(), c.rounds);
    });
}

// ---------------------------------------------------------------------------
// client multiplexing (v4 lanes): one socket, many virtual clients
// ---------------------------------------------------------------------------

/// The v4 pin: spreading the cohort over protocol *lanes* instead of
/// sockets changes nothing observable. For every algorithm, a 2-socket ×
/// 2-lane multiplexed run is bit-identical to the per-connection run
/// (4 sockets × 1 lane) AND to the in-process driver — trajectory,
/// final parameters, and analytic accounting included.
#[test]
fn multiplexed_lanes_bit_identical_for_every_algorithm() {
    with_session(|s| {
        for alg in Algorithm::all() {
            let n_clients = if alg.is_decoupled() { 4 } else { 3 };
            let c = cfg(alg, n_clients);
            let name = alg.name();
            let (rec, theta_l, theta_s) = in_process(s, &c);
            let (mux, mux_clients) = net_run_virtual(s, &c, 2, 2);
            let (flat, _) = net_run(s, &c, 4);
            assert_eq!(mux.lanes, 4, "{name}: 2 conns x 2 lanes");
            assert_eq!(mux.connections, 2);
            for rep in &mux_clients {
                assert_eq!(rep.lanes, 2);
                assert_eq!(
                    rep.lane_clients.iter().sum::<usize>(),
                    rep.assigned.len(),
                    "{name}: every assigned client sits on some lane"
                );
            }
            for (a, b) in rec.rounds.iter().zip(&mux.record.rounds) {
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{name}: train loss vs in-process, round {}",
                    a.round
                );
                assert_eq!(
                    a.eval_metric.to_bits(),
                    b.eval_metric.to_bits(),
                    "{name}: eval vs in-process, round {}",
                    a.round
                );
                assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            }
            assert_eq!(theta_l, mux.final_theta_l, "{name}: θ_l");
            assert_eq!(theta_s, mux.final_theta_s, "{name}: θ_s");
            // and identical to the same cohort spread over 4 sockets
            assert_eq!(flat.lanes, 4);
            assert_eq!(
                flat.final_theta_l, mux.final_theta_l,
                "{name}: θ_l, lanes vs sockets"
            );
            assert_eq!(flat.final_theta_s, mux.final_theta_s);
            for (a, b) in
                flat.record.rounds.iter().zip(&mux.record.rounds)
            {
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
                assert_eq!(a.eval_metric.to_bits(), b.eval_metric.to_bits());
                assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
            }
        }
    });
}

/// Failure injection on a multiplexed socket: two lanes race a full
/// server queue. Every drop surfaces as a typed NACK on the lane that
/// uploaded it, the per-lane counters sum to the server's drop count,
/// and the run still completes every round.
#[test]
fn two_lanes_one_socket_race_full_queue() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 4);
        c.upload_every = 1; // 4 uploads per client per round
        c.queue_capacity = 2; // 16 uploads/round contend for 2 slots
        let (net, clients) = net_run_virtual(s, &c, 1, 2);
        assert_eq!(net.connections, 1);
        assert_eq!(net.lanes, 2);
        let dropped = net.record.summary["queue_dropped"] as u64;
        assert!(dropped > 0, "capacity 2 must drop uploads");
        assert_eq!(net.nacks_sent, dropped, "every drop sends one NACK");
        let rep = &clients[0];
        assert_eq!(rep.lane_nacks.len(), 2);
        assert_eq!(
            rep.lane_nacks.iter().sum::<u64>(),
            dropped,
            "NACKs land on the lane that uploaded"
        );
        // both lanes own clients and both worked every round
        assert_eq!(rep.lane_clients, vec![2, 2]);
        assert!(rep.lane_phases.iter().all(|&p| p == (c.rounds * 2) as u64));
        assert_eq!(net.record.rounds.len(), c.rounds);
    });
}

/// The `(conn, lane)` seq-validation regression pin: in `--drain stream`
/// runs every upload travels as `SmashedSeq` with a per-lane sequence
/// number starting at 1 — two lanes interleaving on ONE socket therefore
/// both send seq 1, 2, ... and a dispatcher that keyed the counter on
/// the connection alone would reject the second lane's first upload as a
/// replay. The run must complete with zero NACKs and the client-side
/// trajectory must still match the in-process barrier reference bitwise.
#[test]
fn interleaved_lane_seqs_validate_per_lane_not_per_conn() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 4);
        c.drain = heron_sfl::coordinator::drain::DrainMode::Stream;
        let (rec, theta_l, _) = in_process(
            s,
            &RunConfig {
                drain: heron_sfl::coordinator::drain::DrainMode::Barrier,
                ..c.clone()
            },
        );
        let (net, clients) = net_run_virtual(s, &c, 1, 2);
        assert_eq!(net.lanes, 2);
        assert_eq!(net.nacks_sent, 0);
        assert_eq!(clients[0].nacks, 0);
        assert_eq!(net.record.rounds.len(), c.rounds);
        // client side is drain-independent (see drain_stream.rs): the
        // seq-accepted stream run reproduces the barrier θ_l bit for bit
        assert_eq!(theta_l, net.final_theta_l, "θ_l");
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
    });
}

#[test]
fn client_reports_observe_the_run() {
    with_session(|s| {
        let c = cfg(Algorithm::Heron, 4);
        let (net, clients) = net_run(s, &c, 2);
        assert_eq!(net.connections, 2);
        assert_eq!(clients.len(), 2);
        for rep in &clients {
            assert_eq!(rep.assigned.len(), 2, "round-robin assignment");
            assert_eq!(rep.rounds, c.rounds);
            assert_eq!(rep.phases, (c.rounds * 2) as u64);
            assert_eq!(rep.shutdown_reason, "run complete");
            assert!(rep.wire.bytes_sent > 0 && rep.wire.bytes_recv > 0);
        }
        // client-side and server-side byte counts agree (loopback is
        // lossless): what clients sent is what the server received
        let client_sent: u64 =
            clients.iter().map(|r| r.wire.bytes_sent).sum();
        let client_recv: u64 =
            clients.iter().map(|r| r.wire.bytes_recv).sum();
        assert_eq!(client_sent, net.wire.bytes_recv);
        assert_eq!(client_recv, net.wire.bytes_sent);
    });
}
