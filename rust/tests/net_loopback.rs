//! End-to-end tests of the `heron-net` subsystem over in-memory loopback
//! transports (every frame still encodes/decodes, so byte counters
//! measure the real wire format):
//!
//! * **bit-identity** — for every algorithm, a networked run (multiple
//!   client "processes" on threads) reproduces the in-process
//!   `Driver::run` trajectory bit for bit;
//! * **accounting cross-check** — measured wire bytes per round equal the
//!   analytic `CostBook` comm bytes plus an explicitly pinned protocol
//!   overhead (frame headers, acks, barriers, targets, …), so silent
//!   drift between `accounting.rs` and the real protocol fails a test;
//! * **NACK failure injection** — a pinned queue capacity makes the
//!   server drop uploads; the typed NACKs seen by clients must equal the
//!   server-side drop count in `QueueStats`.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::net::transport::{loopback_pair, Transport};
use heron_sfl::net::wire::FRAME_OVERHEAD;
use heron_sfl::net::{run_client, serve_transports, ClientReport, NetReport};
use heron_sfl::runtime::Session;

mod common;
use common::with_session;

fn cfg(alg: Algorithm, n_clients: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: alg,
        n_clients,
        rounds: 2,
        local_steps: 4,
        upload_every: 2,
        align_every: 1, // FSL-SAGE: every upload produces cut-grad feedback
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 1,
        dataset_size: 1024,
        eval_every: 1,
        workers: 1,
        ..Default::default()
    }
}

/// Run the experiment over `n_conns` loopback connections, clients on
/// threads — the in-memory analogue of `serve` + N × `connect`.
fn net_run(
    session: &Session,
    cfg: &RunConfig,
    n_conns: usize,
) -> (NetReport, Vec<ClientReport>) {
    let mut server_ends: Vec<Box<dyn Transport>> = Vec::new();
    let mut client_ends = Vec::new();
    for _ in 0..n_conns {
        let (s, c) = loopback_pair();
        server_ends.push(Box::new(s));
        client_ends.push(c);
    }
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_transports(session, cfg.clone(), server_ends, "net")
        });
        let clients: Vec<_> = client_ends
            .into_iter()
            .enumerate()
            .map(|(i, c)| {
                scope.spawn(move || {
                    run_client(session, Box::new(c), &format!("edge-{i}"))
                })
            })
            .collect();
        let report = server.join().expect("server panicked").expect("server");
        let client_reports = clients
            .into_iter()
            .map(|h| h.join().expect("client panicked").expect("client"))
            .collect();
        (report, client_reports)
    })
}

fn in_process(
    session: &Session,
    cfg: &RunConfig,
) -> (heron_sfl::metrics::RunRecord, Vec<f32>, Vec<f32>) {
    let mut driver = Driver::new(session, cfg.clone()).unwrap();
    let rec = driver.run("inproc").unwrap();
    (rec, driver.theta_l.clone(), driver.theta_s.clone())
}

fn assert_trajectories_match(alg: Algorithm, n_conns: usize, n_clients: usize) {
    with_session(|s| {
        let c = cfg(alg, n_clients);
        let (rec, theta_l, theta_s) = in_process(s, &c);
        let (net, _) = net_run(s, &c, n_conns);
        let name = alg.name();
        assert_eq!(
            net.record.rounds.len(),
            rec.rounds.len(),
            "{name}: round count"
        );
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(
                a.train_loss.to_bits(),
                b.train_loss.to_bits(),
                "{name}: train loss, round {}",
                a.round
            );
            assert_eq!(
                a.eval_metric.to_bits(),
                b.eval_metric.to_bits(),
                "{name}: eval metric, round {}",
                a.round
            );
            assert_eq!(
                a.comm_bytes_cum, b.comm_bytes_cum,
                "{name}: analytic comm, round {}",
                a.round
            );
        }
        assert_eq!(theta_l, net.final_theta_l, "{name}: θ_l");
        assert_eq!(theta_s, net.final_theta_s, "{name}: θ_s");
        assert_eq!(
            rec.summary["comm_bytes"], net.record.summary["comm_bytes"],
            "{name}: summary comm"
        );
        assert_eq!(
            rec.summary["client_flops"], net.record.summary["client_flops"],
            "{name}: summary flops"
        );
        // the networked run must actually have moved bytes
        assert!(net.wire.bytes_sent > 0 && net.wire.bytes_recv > 0);
        assert!(
            net.record.summary["wire_bytes_sent"] > 0.0,
            "{name}: per-round wire stats missing"
        );
        // in-process runs report zero measured wire traffic
        assert_eq!(rec.summary["wire_bytes_sent"], 0.0);
    });
}

#[test]
fn heron_tcp_loopback_bit_identical_two_conns() {
    // 4 logical clients round-robined over 2 client processes
    assert_trajectories_match(Algorithm::Heron, 2, 4);
}

#[test]
fn cse_fsl_bit_identical() {
    assert_trajectories_match(Algorithm::CseFsl, 2, 4);
}

#[test]
fn fsl_sage_bit_identical_including_alignment() {
    assert_trajectories_match(Algorithm::FslSage, 2, 4);
}

#[test]
fn sflv1_bit_identical_locked_path() {
    assert_trajectories_match(Algorithm::SflV1, 2, 3);
}

#[test]
fn sflv2_bit_identical_locked_path() {
    assert_trajectories_match(Algorithm::SflV2, 2, 3);
}

#[test]
fn partial_participation_keeps_identity_with_idle_conns() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 5);
        c.participation = 0.6; // 3 of 5 participate; some conns sit idle
        c.rounds = 3;
        let (rec, theta_l, _) = in_process(s, &c);
        let (net, _) = net_run(s, &c, 3);
        for (a, b) in rec.rounds.iter().zip(&net.record.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.comm_bytes_cum, b.comm_bytes_cum);
        }
        assert_eq!(theta_l, net.final_theta_l);
    });
}

// ---------------------------------------------------------------------------
// accounting cross-check: measured wire bytes vs analytic CostBook
// ---------------------------------------------------------------------------

/// Expected measured bytes per round, derived from the protocol layout.
/// Run with one logical client per connection so the θ broadcast maps
/// 1:1 onto the analytic per-participant sync (with multiple clients per
/// connection the broadcast amortizes and measured < analytic — that gap
/// is the point of measuring).
struct Expected {
    sent: u64, // server -> clients
    recv: u64, // clients -> server
}

fn expected_round_bytes(
    s: &Session,
    c: &RunConfig,
    n_conns: usize,
    align_msgs: u64,
) -> Expected {
    let v = s.variant(&c.variant).unwrap();
    let nl = v.size_local() as u64;
    let book = heron_sfl::coordinator::accounting::CostBook::new(
        v,
        c.algorithm,
        c.n_pert as u64,
    );
    let p = c.n_clients as u64; // participation = 1.0 here
    let conns = n_conns as u64;
    let h = c.local_steps as u64;
    let uploads = h / c.upload_every as u64;
    let targets = v.batch as u64; // vision: one i32 label per sample
    let f = FRAME_OVERHEAD;

    let barrier = f + 8 + 4 * p; // round + vec<u32> participants
    let summary = f + 28;
    let model_down = f + 12 + 4 * nl; // round + client + vec<f32> θ
    let model_up = model_down;
    // ids(12) + two length-prefixed vectors (smashed f32s, target i32s)
    let smashed = f + 20 + book.smashed_bytes + 4 * targets;
    let ack = f + 17; // ids + bool + empty reason string
    let zo_update = f + 8 + (4 + 4 * h) + (4 + 4 * h); // ids + seeds + scalars
    let local_done = f + 40;
    let cut_grad = f + 20 + book.cutgrad_bytes; // ids + loss + vec<f32> g
    let align_grad = f + 12 + book.cutgrad_bytes; // ids + vec<f32> g

    if c.algorithm.is_decoupled() {
        Expected {
            sent: conns * (barrier + summary)
                + conns * model_down
                + p * uploads * ack
                + align_msgs * align_grad,
            recv: p * uploads * smashed
                + p * (zo_update + model_up + local_done)
                + align_msgs * model_up,
        }
    } else {
        Expected {
            sent: conns * (barrier + summary)
                + p * model_down // per-participant locked kickoff
                + p * h * cut_grad,
            recv: p * h * smashed + p * model_up,
        }
    }
}

#[test]
fn measured_wire_bytes_match_analytic_plus_pinned_overhead() {
    with_session(|s| {
        for alg in Algorithm::all() {
            let n_clients = 3;
            let c = cfg(alg, n_clients);
            let (net, _) = net_run(s, &c, n_clients); // 1 client per conn
            let v = s.variant(&c.variant).unwrap();
            let book = heron_sfl::coordinator::accounting::CostBook::new(
                v,
                c.algorithm,
                c.n_pert as u64,
            );
            // FSL-SAGE emits one feedback per cut-grad upload: uploads at
            // steps k, 2k, ... where step % (k * align_every) == 0
            let uploads = (c.local_steps / c.upload_every) as u64;
            let align_msgs = if alg == Algorithm::FslSage {
                n_clients as u64 * uploads
            } else {
                0
            };
            let want = expected_round_bytes(s, &c, n_clients, align_msgs);

            // the analytic CostBook number for the same round, from the
            // same formulas the in-process counter uses
            let p = n_clients as u64;
            let analytic_round = match alg {
                Algorithm::SflV1 | Algorithm::SflV2 => {
                    p * (c.local_steps as u64
                        * (book.smashed_bytes + book.cutgrad_bytes)
                        + book.comm_per_round_sync())
                }
                _ => {
                    p * (uploads * book.smashed_bytes
                        + book.comm_per_round_sync())
                        + align_msgs * book.cutgrad_bytes
                }
            };

            for (round, t) in net.record.rounds.iter().enumerate() {
                let delta = if round == 0 {
                    t.comm_bytes_cum
                } else {
                    t.comm_bytes_cum
                        - net.record.rounds[round - 1].comm_bytes_cum
                };
                assert_eq!(
                    delta,
                    analytic_round,
                    "{}: analytic round formula drifted (round {round})",
                    alg.name()
                );
            }

            // measured per-round traffic (server view), recorded in the
            // run summary as cumulative sums over RoundTiming.wire
            let rounds = c.rounds as u64;
            let measured_sent =
                net.record.summary["wire_bytes_sent"] as u64;
            let measured_recv =
                net.record.summary["wire_bytes_recv"] as u64;
            assert_eq!(
                measured_sent,
                want.sent * rounds,
                "{}: server->client bytes (analytic {} + overhead {})",
                alg.name(),
                analytic_round,
                want.sent as i64 - analytic_round as i64,
            );
            assert_eq!(
                measured_recv,
                want.recv * rounds,
                "{}: client->server bytes",
                alg.name()
            );
        }
    });
}

// ---------------------------------------------------------------------------
// failure injection: queue capacity → typed NACKs
// ---------------------------------------------------------------------------

#[test]
fn queue_drops_surface_as_typed_nacks() {
    with_session(|s| {
        let mut c = cfg(Algorithm::Heron, 3);
        c.upload_every = 1; // 4 uploads per client per round
        c.queue_capacity = 2; // 12 uploads/round contend for 2 slots
        let (net, clients) = net_run(s, &c, 3);
        let dropped = net.record.summary["queue_dropped"] as u64;
        assert!(dropped > 0, "capacity 2 must drop uploads");
        assert_eq!(net.nacks_sent, dropped, "every drop sends one NACK");
        let client_nacks: u64 = clients.iter().map(|r| r.nacks).sum();
        assert_eq!(client_nacks, dropped, "every NACK reaches a client");
        // conservation: every upload is either enqueued or dropped
        let enqueued = net.record.summary["queue_enqueued"] as u64;
        let total_uploads =
            (c.n_clients * c.local_steps * c.rounds) as u64;
        assert_eq!(enqueued + dropped, total_uploads);
        // the run still completes every round
        assert_eq!(net.record.rounds.len(), c.rounds);
    });
}

#[test]
fn client_reports_observe_the_run() {
    with_session(|s| {
        let c = cfg(Algorithm::Heron, 4);
        let (net, clients) = net_run(s, &c, 2);
        assert_eq!(net.connections, 2);
        assert_eq!(clients.len(), 2);
        for rep in &clients {
            assert_eq!(rep.assigned.len(), 2, "round-robin assignment");
            assert_eq!(rep.rounds, c.rounds);
            assert_eq!(rep.phases, (c.rounds * 2) as u64);
            assert_eq!(rep.shutdown_reason, "run complete");
            assert!(rep.wire.bytes_sent > 0 && rep.wire.bytes_recv > 0);
        }
        // client-side and server-side byte counts agree (loopback is
        // lossless): what clients sent is what the server received
        let client_sent: u64 =
            clients.iter().map(|r| r.wire.bytes_sent).sum();
        let client_recv: u64 =
            clients.iter().map(|r| r.wire.bytes_recv).sum();
        assert_eq!(client_sent, net.wire.bytes_recv);
        assert_eq!(client_recv, net.wire.bytes_sent);
    });
}
