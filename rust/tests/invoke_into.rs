//! Bit-identity of the zero-allocation invoke path.
//!
//! `Session::invoke_into` (borrowed inputs, reused output slots) and the
//! feature-plan cache may change *when* work happens and *where* results
//! land, but never a single output bit. This suite pins that contract:
//!
//! * every entry of every variant produces byte-identical outputs through
//!   `invoke` (cold), `invoke` again (cache-hit), and `invoke_into` with
//!   dirty, wrong-arity output slots (twice, to exercise buffer reuse);
//! * the HERON (round, client, step) trajectory is bit-identical at
//!   1/4/8 workers while the cache is live, and the run records hits;
//! * `Session::warmup` rejects entry names the variant does not provide.

use heron_sfl::coordinator::algorithms::Algorithm;
use heron_sfl::coordinator::config::RunConfig;
use heron_sfl::coordinator::round::Driver;
use heron_sfl::golden;
use heron_sfl::runtime::tensor::{TensorRef, TensorValue};

mod common;
use common::with_session;

/// Assert two tensor values are byte-for-byte identical (f32 compared on
/// bit patterns, so even NaN payloads or signed zeros would be caught).
fn assert_bits_eq(a: &TensorValue, b: &TensorValue, ctx: &str) {
    match (a, b) {
        (TensorValue::F32(x), TensorValue::F32(y)) => {
            assert_eq!(x.len(), y.len(), "{ctx}: f32 length");
            for (i, (u, v)) in x.iter().zip(y).enumerate() {
                assert_eq!(
                    u.to_bits(),
                    v.to_bits(),
                    "{ctx}: f32[{i}] {u} vs {v}"
                );
            }
        }
        (TensorValue::I32(x), TensorValue::I32(y)) => {
            assert_eq!(x, y, "{ctx}: i32 payload");
        }
        (TensorValue::ScalarF32(x), TensorValue::ScalarF32(y)) => {
            assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: scalar {x} vs {y}");
        }
        (TensorValue::ScalarI32(x), TensorValue::ScalarI32(y)) => {
            assert_eq!(x, y, "{ctx}: scalar i32");
        }
        (a, b) => panic!("{ctx}: variant mismatch {a:?} vs {b:?}"),
    }
}

#[test]
fn invoke_into_and_cache_bit_identical_for_every_entry() {
    with_session(|s| {
        for (vname, v) in &s.manifest.variants {
            for (ename, espec) in &v.entries {
                let ctx = format!("{vname}/{ename}");
                let inputs: Vec<TensorValue> = espec
                    .inputs
                    .iter()
                    .enumerate()
                    .map(|(i, spec)| {
                        golden::bench_input(s, vname, spec, i, &v.task)
                            .unwrap()
                    })
                    .collect();
                // cold invoke (first touch may be a cache miss), then a
                // warm one (hit path) — must match exactly
                let cold = s.invoke(vname, ename, &inputs).unwrap();
                let warm = s.invoke(vname, ename, &inputs).unwrap();
                assert_eq!(cold.len(), espec.outputs.len(), "{ctx}: arity");
                for (i, (a, b)) in cold.iter().zip(&warm).enumerate() {
                    assert_bits_eq(a, b, &format!("{ctx} warm out{i}"));
                }
                // invoke_into with deliberately dirty, wrong-arity slots
                let refs: Vec<TensorRef> =
                    inputs.iter().map(|t| t.view()).collect();
                let mut outs = vec![
                    TensorValue::F32(vec![9.25; 3]),
                    TensorValue::ScalarI32(-7),
                    TensorValue::I32(vec![1, 2]),
                    TensorValue::F32(Vec::new()),
                ];
                s.invoke_into(vname, ename, &refs, &mut outs).unwrap();
                assert_eq!(outs.len(), espec.outputs.len(), "{ctx}: arity");
                for (i, (a, b)) in cold.iter().zip(&outs).enumerate() {
                    assert_bits_eq(a, b, &format!("{ctx} into out{i}"));
                }
                // second invoke_into reuses the slot buffers in place
                s.invoke_into(vname, ename, &refs, &mut outs).unwrap();
                for (i, (a, b)) in cold.iter().zip(&outs).enumerate() {
                    assert_bits_eq(a, b, &format!("{ctx} reuse out{i}"));
                }
            }
        }
    })
}

fn heron_cfg(workers: usize) -> RunConfig {
    RunConfig {
        variant: "cnn_c1".into(),
        algorithm: Algorithm::Heron,
        n_clients: 6,
        rounds: 2,
        local_steps: 2,
        lr_client: 2e-3,
        lr_server: 2e-3,
        mu: 1e-2,
        n_pert: 2,
        dataset_size: 1024,
        eval_every: 1,
        workers,
        ..Default::default()
    }
}

#[test]
fn cached_trajectory_bit_identical_across_worker_counts() {
    // the fingerprint covers θ_l, θ_s, every per-step loss, and the eval
    // metrics — any cache- or scratch-induced divergence shows up here
    let fp = |workers: usize| {
        with_session(|s| {
            let mut d = Driver::new(s, heron_cfg(workers)).unwrap();
            let rec = d.run(&format!("bitid-w{workers}")).unwrap();
            let losses: Vec<f64> =
                rec.rounds.iter().map(|r| r.train_loss).collect();
            let metrics: Vec<f64> =
                rec.rounds.iter().map(|r| r.eval_metric).collect();
            (d.theta_l.clone(), d.theta_s.clone(), losses, metrics)
        })
    };
    let base = fp(1);
    for workers in [4, 8] {
        let other = fp(workers);
        assert_eq!(base.0, other.0, "theta_l differs at workers={workers}");
        assert_eq!(base.1, other.1, "theta_s differs at workers={workers}");
        assert_eq!(base.2, other.2, "losses differ at workers={workers}");
        assert_eq!(base.3, other.3, "metrics differ at workers={workers}");
    }
    // the runs above reused batches (uploads + repeated eval), so the
    // feature-plan cache must have observed traffic and scored hits
    with_session(|s| {
        let st = s.stats();
        assert!(
            st.feature_cache_hits > 0,
            "expected feature-cache hits, got {st:?}"
        );
        assert!(st.feature_cache_misses > 0, "cache never missed? {st:?}");
        assert!(st.alloc_avoided_bytes > 0);
        let rate = st.feature_cache_hit_rate();
        assert!((0.0..=1.0).contains(&rate));
    });
}

#[test]
fn warmup_rejects_unknown_entries() {
    with_session(|s| {
        assert!(s.warmup("cnn_c1", &["zo_step", "client_fwd"]).is_ok());
        let err = s.warmup("cnn_c1", &["zo_stpe"]); // typo'd entry
        assert!(err.is_err(), "typo'd entry must not warm up");
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("zo_stpe"), "error should name the entry: {msg}");
        // entry that exists for cnn_c1 but not for the reduced cnn_c2
        assert!(s.warmup("cnn_c2", &["server_step_cutgrad"]).is_err());
        assert!(s.warmup("no_such_variant", &["zo_step"]).is_err());
    })
}
